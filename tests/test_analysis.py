"""Static-analysis subsystem tests (ISSUE 6): diagnostics framework,
seeded-fault detection across all four pass families, guard matching
and structure-class splits, DSE pruning, manifest/stale handling, and
the bundled-arch clean matrix."""
import dataclasses
import json
import os
import shutil

import pytest

from repro import ModelSpec, ParallelCfg, Scenario, TPU_V5E
from repro.analysis import (RULES, Report, check_guards, check_schedule,
                            check_trace_dir, lint_graph)
from repro.analysis.diagnostics import ERROR, INFO, SEVERITIES
from repro.configs import ARCHS, get
from repro.core.assemble import total_layers
from repro.core.compiled import CompiledBackend
from repro.core.dse import enumerate_pool_splits
from repro.core.matcher import InfeasibleConfigError
from repro.core.schedules import build_schedule
from repro.core.stg import Einsum
from repro.core.symbolic import Env
from repro.core.distribute import guards_match

SPEC = ModelSpec(name="tiny-verify", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=2, d_ff=128, vocab=256)


def _scenario():
    return Scenario(SPEC).train(batch=8, seq=32)


@pytest.fixture(scope="module")
def clean_dir(tmp_path_factory):
    """One clean expanded pp=2 export shared by every fault test."""
    d = str(tmp_path_factory.mktemp("clean"))
    tr = _scenario().parallel(dp=2, pp=2, microbatches=2).trace()
    tr.export_chakra(d, expand_microbatches=True)
    return d


def _mutated(clean_dir, tmp_path, fn, fname="rank1.json"):
    """Copy the clean export and apply ``fn`` to one rank's trace dict."""
    d = str(tmp_path)
    for f in os.listdir(clean_dir):
        shutil.copy(os.path.join(clean_dir, f), d)
    fp = os.path.join(d, fname)
    with open(fp) as f:
        t = json.load(f)
    fn(t)
    with open(fp, "w") as f:
        json.dump(t, f)
    return check_trace_dir(d)


# --------------------------------------------------------------------------
# diagnostics framework
# --------------------------------------------------------------------------

def test_rule_registry_is_consistent():
    assert len(RULES) >= 20
    for code, r in RULES.items():
        assert r.code == code and code.startswith("STG")
        assert r.severity in SEVERITIES


def test_report_rejects_unregistered_code():
    with pytest.raises(KeyError):
        Report().add("STG999", "no such rule")


def test_report_queries_and_render():
    rep = Report(name="unit")
    assert rep.ok and "OK" in rep.render()
    rep.add("STG007", "just info")
    assert rep.ok and rep.codes() == {"STG007"}      # infos never fail
    d = rep.add("STG301", "dup", node=7, rank=3, fixit="renumber")
    assert not rep.ok and d.severity == ERROR
    text = rep.render()
    assert "STG301" in text and "rank3" in text and "renumber" in text
    with pytest.raises(AssertionError):
        rep.raise_if_errors()


def test_report_extend_merges():
    a, b = Report(), Report()
    a.tally("x", 2)
    b.add("STG301", "dup")
    b.tally("x", 3)
    a.extend(b)
    assert a.checked["x"] == 5 and not a.ok


# --------------------------------------------------------------------------
# graph lint (STG0xx) on seeded faults
# --------------------------------------------------------------------------

def _graph():
    return _scenario().builder().clone().graph


def test_lint_clean_graph():
    rep = lint_graph(_graph(), _scenario().env())
    assert rep.ok and not rep.diagnostics
    assert rep.checked["graph_lint"] > 0


def test_dangling_tensor_detected():
    g = _graph()
    consumed = {t.uid for op in g.ops for t in op.ins}
    victim = next(op for op in g.ops
                  if any(t.uid in consumed for t in op.outs))
    g.ops.remove(victim)
    assert "STG001" in lint_graph(g).codes()


def test_graph_cycle_detected():
    g = _graph()
    prod = {t.uid: op for op in g.ops for t in op.outs}
    for op in g.ops:
        srcs = [prod[t.uid] for t in op.ins
                if t.uid in prod and prod[t.uid] is not op]
        if srcs:
            srcs[0].ins.append(op.outs[0])       # producer <-> consumer loop
            break
    assert "STG003" in lint_graph(g).codes()


def test_unbound_symbol_detected():
    rep = lint_graph(_graph(), Env())            # nothing bound
    assert "STG004" in rep.codes()


def test_einsum_dim_mismatch_detected():
    g = _graph()
    e = next(op for op in g.ops
             if isinstance(op, Einsum) and len(op.in_specs) >= 2)
    e.in_specs = [e.in_specs[0], e.in_specs[0]] + list(e.in_specs[2:])
    assert "STG005" in lint_graph(g).codes()


def test_kv_cache_appends_are_not_dead_code():
    """Decode-mode cache writes are sink-tagged, not STG002 warnings."""
    sc = Scenario(SPEC).decode(batch=4, kv_len=64)
    rep = lint_graph(sc.builder().clone().graph)
    assert not rep.diagnostics, rep.render()


# --------------------------------------------------------------------------
# guards: contradiction check, matcher behavior, structure-class splits
# --------------------------------------------------------------------------

def test_check_guards_contradiction():
    guards = {(12, ("tp",)): True}                # 12 % 8 != 0: recorded lie
    cfg = ParallelCfg(axes={"tp": 8}, tp_axis="tp")
    assert not guards_match(guards, cfg)
    rep = check_guards(guards, cfg)
    assert rep.codes() == {"STG006"} and not rep.ok
    ok_cfg = ParallelCfg(axes={"tp": 4}, tp_axis="tp")
    assert guards_match(guards, ok_cfg)
    assert check_guards(guards, ok_cfg).ok


def test_structure_class_splits_on_guard_flip():
    """Two configs with the same structure key but a flipped divisibility
    guard (GQA: 2 kv heads % tp) must compile separate programs, and a
    repeat lookup must replay the cached one."""
    sc = _scenario()
    src = sc.builder()
    eng = CompiledBackend(lambda: src.clone().graph, sc.env(),
                          n_layers=total_layers(SPEC))
    ca = ParallelCfg(axes={"tp": 2}, tp_axis="tp")
    cb = ParallelCfg(axes={"tp": 4}, tp_axis="tp")
    assert eng._structure_key(ca) == eng._structure_key(cb)
    pa, pb = eng.program(ca), eng.program(cb)
    assert eng.compiles == 2 and pa.guards != pb.guards
    assert pa.guards[(2, ("tp",))] is True       # kv heads divide tp=2
    assert pb.guards[(2, ("tp",))] is False      # ... but not tp=4
    eng.program(ca)
    assert eng.hits == 1 and eng.compiles == 2
    # each program's guards are self-consistent for its own config
    assert check_guards(pa.guards, ca).ok
    assert check_guards(pb.guards, cb).ok
    # replaying a's program for b's config is exactly what STG006 flags
    assert not check_guards(pa.guards, cb).ok


def test_decode_series_rejects_guard_flip_in_range():
    """A KV-dependent guard flipping inside the decode range (cp=2 over
    kv 32..34) means no single lowered program covers the generation —
    the series must refuse instead of silently mis-costing."""
    job = (Scenario(SPEC).prefill(batch=4, seq=32).parallel(cp=2)
           .generation(out_tokens=4))
    with pytest.raises(InfeasibleConfigError, match="KV-dependent"):
        job.evaluate(TPU_V5E)


def test_decode_series_guard_stable_control():
    """Same range without the KV-sharding axis evaluates fine."""
    job = (Scenario(SPEC).prefill(batch=4, seq=32).parallel(tp=2)
           .generation(out_tokens=4))
    res = job.evaluate(TPU_V5E)
    assert res.tokens_per_s > 0


# --------------------------------------------------------------------------
# schedule checks (STG2xx) on seeded faults
# --------------------------------------------------------------------------

def _reslot(sched, timelines):
    return dataclasses.replace(
        sched, timelines=tuple(tuple(t) for t in timelines))


def test_schedule_clean():
    for name in ("gpipe", "1f1b", "interleaved", "zb-h1"):
        rep = check_schedule(build_schedule(name, 2, 4, 2))
        assert rep.ok and not rep.diagnostics, (name, rep.render())


def test_schedule_missing_slot():
    s = build_schedule("1f1b", 2, 4, 1)
    tl = [list(t) for t in s.timelines]
    tl[1].pop(3)
    rep = check_schedule(_reslot(s, tl))
    assert "STG204" in rep.codes()


def test_schedule_deadlock_and_phase_order():
    # stage0 forwards mb0 only after its backward: the cross-stage event
    # graph can never make progress
    s = build_schedule("1f1b", 2, 4, 1)
    tl = [list(t) for t in s.timelines]
    f0 = next(x for x in tl[0] if x.kind == "fwd" and x.mb == 0)
    tl[0].remove(f0)
    tl[0].append(f0)
    rep = check_schedule(_reslot(s, tl))
    assert "STG201" in rep.codes() and "STG202" in rep.codes()


def test_schedule_bwd_split_order():
    z = build_schedule("zb-h1", 2, 4, 1)
    tl = [list(t) for t in z.timelines]
    stage = tl[1]
    i = next(i for i, sl in enumerate(stage) if sl.kind == "bwd_in")
    ref = stage[i]
    j = next(k for k, sl in enumerate(stage)
             if sl.kind == "bwd_w" and sl.mb == ref.mb
             and sl.vstage == ref.vstage)
    stage[i], stage[j] = stage[j], stage[i]
    rep = check_schedule(_reslot(z, tl))
    assert "STG203" in rep.codes()


# --------------------------------------------------------------------------
# chakra trace checks (STG3xx): the acceptance's seeded corruptions
# --------------------------------------------------------------------------

def test_clean_export_verifies(clean_dir):
    rep = check_trace_dir(clean_dir)
    assert rep.ok and not rep.diagnostics, rep.render()
    assert rep.checked["trace_files"] == 4


def test_dropped_recv(clean_dir, tmp_path):
    def fault(t):
        i = next(i for i, n in enumerate(t["nodes"])
                 if n["type"] == "COMM_RECV_NODE")
        del t["nodes"][i]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert "STG101" in rep.codes()


def test_duplicate_node_id(clean_dir, tmp_path):
    def fault(t):
        t["nodes"][1]["id"] = t["nodes"][0]["id"]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert "STG301" in rep.codes()


def test_cyclic_ctrl_dep(clean_dir, tmp_path):
    def fault(t):
        t["nodes"][2]["ctrl_deps"] = [t["nodes"][-1]["id"]]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert "STG303" in rep.codes()


def test_unresolved_dep(clean_dir, tmp_path):
    def fault(t):
        t["nodes"][1]["data_deps"] = [99999999]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert rep.codes() == {"STG302"}


def test_reordered_collective_diverges(clean_dir, tmp_path):
    """Swapping two distinct collectives on one rank must be caught as
    SPMD divergence even though the file is internally self-consistent
    (this also pins the spliced-body dedup to exact byte identity — a
    sampled key would group the mutant with its clean siblings)."""
    def fault(t):
        idx = [i for i, n in enumerate(t["nodes"])
               if n["type"] == "COMM_COLL_NODE"]
        i = idx[0]
        j = next(k for k in idx
                 if t["nodes"][k]["name"] != t["nodes"][i]["name"])
        t["nodes"][i], t["nodes"][j] = t["nodes"][j], t["nodes"][i]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert rep.codes() == {"STG307"}
    d = rep.by_code("STG307")[0]
    assert d.rank == 1


def test_microbatch_expansion_inconsistent(clean_dir, tmp_path):
    def fault(t):
        i = next(i for i, n in enumerate(t["nodes"])
                 if n.get("attrs", {}).get("mb") == 1)
        del t["nodes"][i]
    rep = _mutated(clean_dir, tmp_path, fault)
    assert "STG304" in rep.codes()


def test_attr_schema_violation(clean_dir, tmp_path):
    def fault(t):
        n = next(n for n in t["nodes"] if n["type"] == "COMP_NODE")
        n["attrs"]["num_ops"] = "not-a-number"
    rep = _mutated(clean_dir, tmp_path, fault)
    assert rep.codes() == {"STG306"}


def test_stale_file_flagged(clean_dir, tmp_path):
    d = str(tmp_path)
    for f in os.listdir(clean_dir):
        shutil.copy(os.path.join(clean_dir, f), d)
    shutil.copy(os.path.join(d, "rank0.json"), os.path.join(d, "rank99.json"))
    rep = check_trace_dir(d)
    assert rep.codes() == {"STG308"}
    assert rep.by_code("STG308")[0].rank == 99


def test_manifest_missing_file_flagged(clean_dir, tmp_path):
    d = str(tmp_path)
    for f in os.listdir(clean_dir):
        shutil.copy(os.path.join(clean_dir, f), d)
    os.remove(os.path.join(d, "rank3.json"))
    rep = check_trace_dir(d)
    assert "STG308" in rep.codes()
    assert any("missing" in di.message for di in rep.by_code("STG308"))


def test_empty_dir(tmp_path):
    rep = check_trace_dir(str(tmp_path))
    assert rep.codes() == {"STG309"}


# --------------------------------------------------------------------------
# disaggregated jobs: kv-transfer matching (STG305)
# --------------------------------------------------------------------------

def _disagg_job():
    return (Scenario(SPEC).prefill(batch=4, seq=32).generation(out_tokens=8)
            .disaggregate(prefill_pool=dict(tp=2), decode_pool=dict(dp=2),
                          kv_transfer=1e9))


def test_disaggregated_job_verifies_clean():
    rep = _disagg_job().verify()
    assert rep.ok and not rep.diagnostics, rep.render()


def test_orphan_kv_transfer(tmp_path):
    d = str(tmp_path)
    _disagg_job().export_chakra(d)
    assert check_trace_dir(d).ok
    for fn in sorted(os.listdir(d)):
        if not fn.startswith("rank"):
            continue
        fp = os.path.join(d, fn)
        with open(fp) as f:
            t = json.load(f)
        kv = [i for i, n in enumerate(t["nodes"])
              if n.get("attrs", {}).get("phase") == "kv_transfer"
              and n["type"] == "COMM_RECV_NODE"]
        if kv:
            del t["nodes"][kv[0]]
            with open(fp, "w") as f:
                json.dump(t, f)
            break
    else:
        pytest.fail("no kv-transfer recv found in the exported job")
    rep = check_trace_dir(d)
    assert "STG305" in rep.codes()


# --------------------------------------------------------------------------
# export manifest / on_stale semantics (satellite 1)
# --------------------------------------------------------------------------

def test_manifest_written_and_complete(clean_dir):
    with open(os.path.join(clean_dir, "manifest.json")) as f:
        man = json.load(f)
    assert man["export"] == "ranks" and man["world"] == 4
    assert set(man["files"]) == {"rank0.json", "rank1.json", "rank2.json",
                                 "rank3.json", "manifest.json"}
    for fn in man["files"]:
        assert os.path.exists(os.path.join(clean_dir, fn))


def test_on_stale_error_clean_ignore(tmp_path):
    d = str(tmp_path)
    tr = _scenario().parallel(dp=2, pp=2, microbatches=2).trace()
    tr.export_chakra(d)
    stale = os.path.join(d, "rank7.json")
    shutil.copy(os.path.join(d, "rank0.json"), stale)
    with pytest.raises(ValueError, match="previous export"):
        tr.export_chakra(d)                          # default: error
    assert os.path.exists(stale)                     # refused before writing
    tr.export_chakra(d, on_stale="clean")
    assert not os.path.exists(stale)
    shutil.copy(os.path.join(d, "rank0.json"), stale)
    tr.export_chakra(d, on_stale="ignore")
    assert os.path.exists(stale)
    assert "STG308" in check_trace_dir(d).codes()    # verifier's catch
    with pytest.raises(ValueError, match="on_stale"):
        tr.export_chakra(d, on_stale="bogus")


def test_job_export_on_stale(tmp_path):
    d = str(tmp_path)
    job = _disagg_job()
    job.export_chakra(d)
    with open(os.path.join(d, "manifest.json")) as f:
        assert json.load(f)["export"] == "job"
    stale = os.path.join(d, "rank9.json")
    shutil.copy(os.path.join(d, "rank0.json"), stale)
    with pytest.raises(ValueError, match="previous export"):
        job.export_chakra(d)
    job.export_chakra(d, on_stale="clean")
    assert not os.path.exists(stale)
    assert check_trace_dir(d).ok


# --------------------------------------------------------------------------
# DSE: pool-split error type, prefilter, verify diagnostics (satellite 2)
# --------------------------------------------------------------------------

def test_enumerate_pool_splits_raises_typed_error():
    with pytest.raises(InfeasibleConfigError, match="world >= 2"):
        enumerate_pool_splits(1)
    assert enumerate_pool_splits(8) == [(1, 7), (2, 6), (4, 4)]


def test_sweep_prefilters_infeasible_microbatching():
    # batch=16, world=4, mb=8: dp=1 (16/8) and dp=2 (8/8) fit; dp=4
    # leaves a per-rank batch of 4 that 8 cannot cut, so those configs
    # never reach the evaluator
    res = Scenario(SPEC).train(batch=16, seq=32).sweep(
        4, microbatches=8, verify=True)
    assert len(res) > 0
    assert res.skipped and all(s.prefiltered for s in res.skipped)
    assert all(s.diagnostics and s.diagnostics[0].code == "STG007"
               for s in res.skipped)
    assert all(d.severity == INFO for s in res.skipped
               for d in s.diagnostics)
    pruned = res.pruned
    assert sum(pruned.values()) == len(res.skipped)
    assert "feasible" in res.summary() and "skipped" in res.summary()


def test_sweep_without_verify_has_no_diagnostics():
    res = Scenario(SPEC).train(batch=16, seq=32).sweep(4, microbatches=8)
    assert res.skipped and all(not s.diagnostics for s in res.skipped)


# --------------------------------------------------------------------------
# the clean matrix: every bundled arch x mode x schedule verifies clean
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ARCHS)
def test_bundled_arch_verifies_clean(name):
    spec = get(name).smoke
    for sched in ("gpipe", "1f1b", "interleaved", "zb-h1"):
        for sc in (Scenario(spec).train(batch=4, seq=32),
                   Scenario(spec).decode(batch=4, kv_len=64)):
            tr = sc.parallel(dp=2, pp=2, microbatches=2,
                             schedule=sched).trace()
            rep = tr.verify(include_graph=True)
            assert rep.ok and not rep.diagnostics, \
                f"{name}/{sc.mode}/{sched}: {rep.render()}"


def test_trace_verify_chakra_mode():
    tr = _scenario().parallel(dp=2, pp=2, microbatches=2).trace()
    rep = tr.verify(chakra=True)
    assert rep.ok and not rep.diagnostics, rep.render()
    assert rep.checked.get("trace_nodes", 0) > 0
