"""Phase-program serving tests: closed-form decode vs per-step replay
(bit-identical spot checks, exact sums), TTFT/TPOT pinned against the
reference pipeline and hand-computed KV math, disaggregated KV-transfer
invariance, O(1)-evaluation guarantees, and the serve/Cap footgun fixes.
"""
import json
import os
import tempfile

import pytest
import sympy as sp

from repro import Job, ModelSpec, MoESpec, Scenario, TPU_V5E
from repro.core.assemble import bind_env, build_graph, total_layers
from repro.core.distribute import distribute
from repro.core.graphdist import apply_pipeline
from repro.core.instantiate import instantiate
from repro.core.memory import kv_cache_bytes
from repro.core.serving import DecodeSeries
from repro.core.simulate import simulate, sum_convex_series

TINY = ModelSpec(name="srv", n_layers=2, d_model=128, n_heads=4,
                 n_kv_heads=2, d_ff=256, vocab=1024)
WINDOWED = ModelSpec(name="srv-win", n_layers=2, d_model=128, n_heads=4,
                     n_kv_heads=2, d_ff=256, vocab=1024, window=96)
MOE = ModelSpec(name="srv-moe", n_layers=2, d_model=128, n_heads=4,
                n_kv_heads=4, d_ff=256, vocab=512,
                moe=MoESpec(n_experts=16, top_k=2, d_expert=64))

BATCH, KV0, STEPS = 4, 64, 32


def _sympy_step(spec, cfg, t, *, batch=BATCH, kv0=KV0):
    """Reference per-step pipeline replay at decode index ``t``."""
    env = bind_env(spec, batch=batch, seq=1, kv_len=kv0 + t, mode="decode")
    g = build_graph(spec, mode="decode").graph
    distribute(g, cfg, env)
    plan = apply_pipeline(g, cfg.pp, total_layers(spec))
    return instantiate(g, cfg, env, plan)


def _series(spec, sc, steps=STEPS, kv0=KV0):
    return DecodeSeries(lambda: sc.builder().graph, spec, sc.cfg,
                        batch=BATCH, kv0=kv0, steps=steps)


# ---- closed form vs per-step replay ---------------------------------------

@pytest.mark.parametrize("spec,t_checks", [
    (TINY, (0, 13, STEPS - 1)),
    (WINDOWED, (0, 31, 32, 33, STEPS - 1)),   # window hits at kv=96 (t=32)
], ids=["dense", "sliding-window"])
def test_decode_series_spot_checks_bit_identical(spec, t_checks):
    """Any individual decode index must replay bit-identically (==) to
    the full per-step sympy pipeline — per-node costs AND simulated
    step time."""
    sc = Scenario(spec).decode(batch=BATCH, kv_len=KV0).parallel(dp=2, tp=2)
    series = _series(spec, sc)
    for t in t_checks:
        wr = _sympy_step(spec, sc.cfg, t)
        wc = series.step_workload(t)
        assert len(wr.nodes) == len(wc.nodes)
        for a, b in zip(wr.nodes, wc.nodes):
            assert a.flops == b.flops, (t, a.name)
            assert a.bytes_accessed == b.bytes_accessed, (t, a.name)
            assert a.out_bytes == b.out_bytes, (t, a.name)
            assert a.comm == b.comm, (t, a.name)
        assert simulate(wr, TPU_V5E).step_time == \
            simulate(wc, TPU_V5E).step_time, t


@pytest.mark.parametrize("spec", [TINY, WINDOWED],
                         ids=["dense", "sliding-window"])
def test_closed_form_sum_matches_per_step_sum(spec):
    """The analytic decode total must equal the explicit sum of every
    per-step replay (exact for the linear stretches; the windowed model
    adds a genuine breakpoint at the window boundary)."""
    sc = Scenario(spec).decode(batch=BATCH, kv_len=KV0).parallel(dp=2, tp=2)
    series = _series(spec, sc)
    total, evals = series.total_time(TPU_V5E)
    brute = sum(simulate(_sympy_step(spec, sc.cfg, t), TPU_V5E).step_time
                for t in range(STEPS))
    assert abs(total - brute) / brute < 1e-9
    assert evals <= 12, f"{evals} evaluations for {STEPS} linear-ish steps"


def test_sum_convex_series_exact_on_linear_and_piecewise():
    total, n = sum_convex_series(lambda t: 3.0 + 0.5 * t, 0, 511)
    assert total == pytest.approx(3.0 * 512 + 0.5 * 511 * 512 / 2, rel=1e-12)
    assert n == 3                                 # endpoints + midpoint
    f = lambda t: max(10.0, 2.0 * t)              # breakpoint at t=5
    total, n = sum_convex_series(f, 0, 100)
    assert total == pytest.approx(sum(f(t) for t in range(101)), rel=1e-12)
    assert n < 40


def test_decode_series_is_o1_in_steps():
    """512 decode steps must cost O(1) engine work: 2 lowerings (range
    endpoints' guard check) and a handful of samples — not 512."""
    sc = Scenario(TINY).decode(batch=BATCH, kv_len=KV0).parallel(dp=2)
    series = _series(TINY, sc, steps=512)
    _, evals = series.total_time(TPU_V5E)
    assert series.engine_calls <= 2
    assert evals <= 12


# ---- Job metrics -----------------------------------------------------------

def test_job_ttft_tpot_pinned_against_reference():
    """TTFT == the prefill phase's simulated time; TPOT == the mean of
    the per-step reference replays; tokens/s follows from both."""
    sc = Scenario(TINY).prefill(batch=BATCH, seq=KV0).parallel(dp=2, tp=2)
    job = sc.generation(out_tokens=STEPS + 1)
    res = job.evaluate(TPU_V5E)

    ttft_ref = sc.trace().simulate(TPU_V5E).step_time
    assert res.ttft == ttft_ref
    dec_ref = [simulate(_sympy_step(TINY, sc.cfg, t), TPU_V5E).step_time
               for t in range(STEPS)]
    assert res.tpot == pytest.approx(sum(dec_ref) / STEPS, rel=1e-9)
    total_ref = ttft_ref + sum(dec_ref)
    assert res.total_time == pytest.approx(total_ref, rel=1e-9)
    assert res.tokens_per_s == pytest.approx(
        BATCH * (STEPS + 1) / total_ref, rel=1e-9)
    assert res.out_tokens == STEPS + 1
    # decode cost grows with the cache: last step >= first step
    dec = next(p for p in res.phases if p.mode == "decode")
    assert dec.step_last >= dec.step_first


def test_job_kv_bytes_hand_computed():
    """Global KV read by decode index t is hand-computable for GQA:
    2 (k+v) * L * B * (kv0+t) * NKV * DH * 2 bytes (bf16)."""
    sc = Scenario(TINY).prefill(batch=BATCH, seq=KV0).parallel(dp=2, tp=2)
    series = _series(TINY, sc.decode(batch=BATCH, kv_len=KV0))
    for t in (0, 7, STEPS - 1):
        expect = 2 * TINY.n_layers * BATCH * (KV0 + t) \
            * TINY.n_kv_heads * TINY.head_dim * 2
        assert series.kv_bytes(t) == expect
    res = sc.generation(out_tokens=STEPS + 1).evaluate(TPU_V5E)
    assert res.peak_kv_gb == pytest.approx(
        2 * TINY.n_layers * BATCH * (KV0 + STEPS - 1)
        * TINY.n_kv_heads * TINY.head_dim * 2 / 2**30)


def test_kv_transfer_bytes_invariant_under_placement_and_sharding():
    """The prefill→decode handoff ships the GLOBAL cache: bytes must not
    change with the decode pool's sharding or physical placement."""
    sc = Scenario(TINY).prefill(batch=BATCH, seq=KV0)
    job = sc.generation(out_tokens=17)
    seen = set()
    for pool in (dict(tp=4), dict(dp=4), dict(dp=2, tp=2),
                 dict(dp=2, tp=2, pp=1)):
        res = job.disaggregate(prefill_pool=dict(tp=2), decode_pool=pool,
                               kv_transfer=100e9).evaluate(TPU_V5E)
        seen.add(res.kv_transfer_bytes)
    # placement permutations of the same factorization
    for place in (("tp", "dp", "pp"), ("dp", "tp", "pp")):
        dsc = sc.decode(batch=BATCH, kv_len=KV0) \
            .parallel(dp=2, tp=2).placement(*place)
        res = job.disaggregate(prefill_pool=dict(tp=2), decode_pool=dsc,
                               kv_transfer=100e9).evaluate(TPU_V5E)
        seen.add(res.kv_transfer_bytes)
    assert len(seen) == 1, seen
    # and it matches the reference graph-level accounting
    env = bind_env(TINY, batch=BATCH, seq=1, kv_len=KV0, mode="decode")
    g = build_graph(TINY, mode="decode").graph
    cfg = Scenario(TINY).decode(batch=BATCH, kv_len=KV0) \
        .parallel(dp=2, tp=2).cfg
    distribute(g, cfg, env)
    assert seen == {kv_cache_bytes(g, cfg, env)}


def test_disaggregated_timeline_and_export():
    sc = Scenario(TINY).prefill(batch=BATCH, seq=KV0)
    job = sc.generation(out_tokens=9).disaggregate(
        prefill_pool=dict(tp=2), decode_pool=dict(dp=2, tp=2),
        kv_transfer=50e9)
    res = job.evaluate(TPU_V5E)
    assert res.disaggregated
    assert res.kv_transfer_time == pytest.approx(
        res.kv_transfer_bytes / 50e9)
    assert res.total_time == pytest.approx(
        sum(p.time for p in res.phases) + res.kv_transfer_time)

    with tempfile.TemporaryDirectory() as d:
        n = job.export_chakra(d)
        assert n == 2 + 4                     # prefill world + decode world
        man = json.load(open(os.path.join(d, "job.json")))
        assert man["pools"]["prefill"]["world"] == 2
        assert man["pools"]["decode"]["offset"] == 2
        r_pre = json.load(open(os.path.join(d, "rank0.json")))
        r_dec = json.load(open(os.path.join(d, "rank2.json")))
        assert r_pre["pool"] == "prefill" and r_dec["pool"] == "decode"
        sends = [nd for nd in r_pre["nodes"]
                 if nd["type"] == "COMM_SEND_NODE"
                 and nd["attrs"].get("phase") == "kv_transfer"]
        recvs = [nd for nd in r_dec["nodes"]
                 if nd["type"] == "COMM_RECV_NODE"
                 and nd["attrs"].get("phase") == "kv_transfer"]
        assert len(sends) == 1 and len(recvs) == 1
        # per-pool shares sum back to the global handoff
        assert sends[0]["attrs"]["comm_size"] * 2 == \
            pytest.approx(res.kv_transfer_bytes)
        assert recvs[0]["attrs"]["comm_size"] * 4 == \
            pytest.approx(res.kv_transfer_bytes)
        # decode body carries its KV span
        dec_nodes = [nd for nd in r_dec["nodes"]
                     if nd["attrs"].get("phase") == "decode"]
        assert dec_nodes and dec_nodes[0]["attrs"]["kv_start"] == str(KV0)
        assert dec_nodes[0]["attrs"]["steps"] == "8"
        # phase-boundary control deps: the recv gates the decode body
        recv_id = recvs[0]["id"]
        gated = [nd for nd in dec_nodes if recv_id in nd["ctrl_deps"]]
        assert gated, "decode phase must be control-dep-gated on the recv"


def test_colocated_export_single_pool_chain():
    sc = Scenario(TINY).prefill(batch=BATCH, seq=KV0).parallel(dp=2, tp=2)
    job = sc.generation(out_tokens=5)
    with tempfile.TemporaryDirectory() as d:
        n = job.export_chakra(d)
        assert n == 4
        r0 = json.load(open(os.path.join(d, "rank0.json")))
        phases = {nd["attrs"].get("phase") for nd in r0["nodes"]}
        assert phases == {"prefill", "decode"}
        ids = [nd["id"] for nd in r0["nodes"]]
        assert len(ids) == len(set(ids))      # no collisions across phases
        pre_tail = max(nd["id"] for nd in r0["nodes"]
                       if nd["attrs"]["phase"] == "prefill")
        gated = [nd for nd in r0["nodes"]
                 if nd["attrs"]["phase"] == "decode"
                 and pre_tail in nd["ctrl_deps"]]
        assert gated, "decode must chain onto the prefill tail"


def test_job_sweep_out_tokens_and_splits():
    sc = Scenario(TINY).prefill(batch=8, seq=64)
    job = sc.generation(out_tokens=17)
    pts = job.sweep(8, TPU_V5E, out_tokens=(9, 17), max_tp=4, max_pp=1)
    assert pts and {p.out_tokens for p in pts} == {9, 17}
    assert all(pts[i].tokens_per_s >= pts[i + 1].tokens_per_s
               for i in range(len(pts) - 1))
    spts = job.sweep(8, TPU_V5E, splits="auto", max_tp=4, max_pp=1)
    assert spts and all(p.split[0] + p.split[1] == 8 for p in spts)


# ---- satellites: footguns --------------------------------------------------

def test_serve_without_kv_len_raises():
    """Scenario.serve(batch=b) used to silently model a decode step
    against a 1-token cache (bind_env's kv = seq fallback)."""
    with pytest.raises(ValueError, match="kv_len"):
        Scenario(TINY).serve(batch=4)
    with pytest.raises(ValueError, match="kv_len"):
        bind_env(TINY, batch=4, seq=1, mode="decode")
    # prefill fallback (kv = seq) stays
    assert Scenario(TINY).serve(batch=4, seq=128).mode == "prefill"
    env = bind_env(TINY, batch=4, seq=128, mode="prefill")
    assert env[sp.Symbol("Skv", positive=True, integer=True)] == 128


def test_moe_decode_capacity_tracks_routed_tokens():
    """bind_env's train-style Cap = max(1, ceil(B*S*K/E)) floors at one
    token per expert; at decode B*K can be far below E and expert cost
    must scale with the ROUTED token count (B*S*K/E exactly), not the
    expert count — the paper Table IX decode regime."""
    from repro.core.symbolic import sym
    env1 = bind_env(MOE, batch=1, seq=1, kv_len=64, mode="decode")
    assert env1[sym("Cap")] == sp.Rational(2, 16)       # B*K/E = 2/16
    env4 = bind_env(MOE, batch=4, seq=1, kv_len=64, mode="decode")
    assert env4[sym("Cap")] == sp.Rational(8, 16)
    # train binding unchanged (ceil, floored at 1)
    env_t = bind_env(MOE, batch=1, seq=3)
    assert env_t[sym("Cap")] == 1

    def egate_flops(batch):
        w = Scenario(MOE).decode(batch=batch, kv_len=64).trace().workload
        return sum(n.flops for n in w.nodes if n.name == "egate0")

    f1, f4 = egate_flops(1), egate_flops(4)
    assert f4 == pytest.approx(4 * f1, rel=1e-12), \
        "decode MoE cost must be linear in batch (old Cap floor broke this)"
    # absolute scale: E * Cap == routed tokens, so the expert GEMM costs
    # 2 * routed * H * Dffe flops
    assert f1 == pytest.approx(2 * 1 * MOE.moe.top_k * MOE.d_model
                               * MOE.moe.d_expert, rel=1e-12)


def test_moe_decode_table9_expectations():
    """Table IX regression (benchmarks/table9_moe_inference.py's claim,
    pinned here so the Cap rebinding can't silently break it): on
    deepseek-v2 the throughput-optimal EP cluster differs by phase —
    growing 10→40 GPUs *improves* decode tokens/s/GPU while prefill
    tokens/s/GPU degrades (prefill prefers the smaller cluster)."""
    from repro import H100_HGX
    from repro.configs import get
    spec = get("deepseek-v2-236b").spec
    rows = {}
    for gpus in (10, 40):
        ep = Scenario(spec).parallel(dp=gpus, ep=True)
        batch = 13 * gpus
        dec = ep.decode(batch=batch, kv_len=1024).trace().simulate(H100_HGX)
        pre = ep.prefill(batch=batch, seq=1024).trace().simulate(H100_HGX)
        rows[gpus] = (batch / dec.step_time / gpus,
                      batch * 1024 / pre.step_time / gpus)
    assert rows[40][0] > rows[10][0], \
        f"decode must gain from the larger EP cluster: {rows}"
    assert rows[10][1] > rows[40][1], \
        f"prefill must prefer the smaller EP cluster: {rows}"


def test_sweep_handles_prefill_only_and_disaggregated_jobs():
    """Colocated sweep points must be genuinely colocated (no phantom
    KV handoff even when sweeping a disaggregated job), and a
    prefill-only job must sweep without a decode phase to resize."""
    sc = Scenario(TINY).prefill(batch=4, seq=64)
    pts = sc.generation(out_tokens=1).sweep(4, TPU_V5E, max_pp=1)
    assert pts and all(p.out_tokens == 1 for p in pts)
    dj = sc.generation(out_tokens=9).disaggregate(
        prefill_pool=dict(tp=2), decode_pool=dict(dp=2), kv_transfer=1e9)
    for p in dj.sweep(4, TPU_V5E, max_pp=1):
        assert not p.result.disaggregated
        assert p.result.kv_transfer_time == 0.0
    # out_tokens=1 in a swept range degrades to prefill-only, not a crash
    mixed = sc.generation(out_tokens=9).sweep(
        4, TPU_V5E, out_tokens=(1, 9), max_pp=1)
    assert {p.out_tokens for p in mixed} == {1, 9}
    assert all(p.result.tpot == 0.0 for p in mixed if p.out_tokens == 1)


def test_step_sims_respect_algorithm_overrides():
    """step_first/step_last must be computed under the same collective
    algorithms as the phase total: a 1-step decode phase's time equals
    its step_last even with a forced AllReduce algorithm."""
    from repro import H100_HGX_POD
    job = (Scenario(TINY).prefill(batch=BATCH, seq=KV0)
           .parallel(dp=2, tp=2).with_algorithm("AllReduce", "tree")
           .generation(out_tokens=2))
    res = job.evaluate(H100_HGX_POD)
    dec = next(p for p in res.phases if p.mode == "decode")
    assert dec.time == dec.step_last == dec.step_first


def test_local_kv_bytes_account_for_pipeline_stages():
    """A pp rank holds only its own layers' caches: per-rank KV shard
    must shrink with pp (even layer split), never equal the global."""
    flat = Scenario(TINY).decode(batch=BATCH, kv_len=KV0).parallel(tp=2)
    piped = flat.parallel(tp=2, pp=2)
    s_flat, s_pp = _series(TINY, flat), _series(TINY, piped)
    assert s_pp.kv_bytes(0) == s_flat.kv_bytes(0)          # global invariant
    assert s_pp.kv_bytes(0, local=True) == \
        s_flat.kv_bytes(0, local=True) / 2
    env = bind_env(TINY, batch=BATCH, seq=1, kv_len=KV0, mode="decode")
    g = build_graph(TINY, mode="decode").graph
    distribute(g, piped.cfg, env)
    assert kv_cache_bytes(g, piped.cfg, env, local=True) == \
        kv_cache_bytes(g, piped.cfg, env) / 2


def test_decode_phase_requires_kv_growth_consistency():
    sc = Scenario(TINY).prefill(batch=4, seq=64)
    with pytest.raises(ValueError, match="kv_growth"):
        sc.phase(kv_growth=1)                 # prefill can't grow KV
    with pytest.raises(ValueError, match="out_tokens"):
        sc.generation(out_tokens=0)
    with pytest.raises(ValueError, match="serving prompt shape"):
        Scenario(TINY).train(batch=4, seq=64).generation(out_tokens=8)
