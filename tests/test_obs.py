"""Observability layer tests: self-profiling spans, the metrics
registry, REPRO_LOG logging, cache-stat taxonomy (eviction vs staleness
re-wrap), sweep progress callbacks, and the ``repro.obs`` CLI."""
import json
import logging
import threading

import pytest

from repro import Scenario, compiled_cache_stats
from repro.configs import get
from repro.obs import diff, profiled, snapshot, span, take_events, traced
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans


@pytest.fixture(autouse=True)
def _clean_obs():
    obs_spans.disable()
    obs_spans.take_events()
    obs_metrics.reset()
    yield
    obs_spans.disable()
    obs_spans.take_events()
    obs_metrics.reset()


SPEC = get("minitron-8b").smoke


# --------------------------------------------------------------------------
# spans
# --------------------------------------------------------------------------

def test_span_disabled_is_noop_singleton():
    a = span("x")
    b = span("y", k=1)
    assert a is b is obs_spans._NOOP
    with a:
        pass
    assert take_events() == []


def test_span_enabled_records_and_nests():
    with profiled() as prof:
        with span("outer", tag="a"):
            with span("inner"):
                pass
    names = [e.name for e in prof.events]
    assert set(names) == {"outer", "inner"}
    by = {e.name: e for e in prof.events}
    assert by["inner"].depth == by["outer"].depth + 1
    assert by["outer"].args == {"tag": "a"}
    assert by["outer"].dur >= by["inner"].dur >= 0.0


def test_profiled_restores_prior_state_and_isolates_events():
    obs_spans.enable()
    with span("before"):
        pass
    with profiled() as prof:
        with span("during"):
            pass
    assert [e.name for e in prof.events] == ["during"]
    # the outer enabled state survives the context
    assert obs_spans.enabled()
    names = [e.name for e in take_events()]
    assert "before" in names


def test_traced_decorator():
    @traced("my.fn")
    def fn(x):
        return x + 1

    assert fn(1) == 2          # disabled: plain call
    with profiled() as prof:
        assert fn(2) == 3
    assert [e.name for e in prof.events] == ["my.fn"]


def test_profile_totals_subtract_children():
    with profiled() as prof:
        with span("parent"):
            with span("child"):
                pass
    tot = prof.totals()
    assert tot["parent"]["self_s"] <= tot["parent"]["total_s"]
    assert tot["parent"]["self_s"] == pytest.approx(
        tot["parent"]["total_s"] - tot["child"]["total_s"], abs=1e-9)


def test_profile_chrome_trace_validates():
    from repro.obs.timeline import validate_chrome_trace
    with profiled() as prof:
        with span("a"):
            with span("b"):
                pass
    obj = prof.chrome_trace()
    assert validate_chrome_trace(json.loads(json.dumps(obj))) == []
    names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "X"}
    assert names == {"a", "b"}


def test_api_emits_spans():
    with profiled() as prof:
        tr = (Scenario(SPEC).train(batch=32, seq=2048)
              .parallel(pp=2, tp=2, microbatches=4).trace())
        tr.simulate()
        tr.timeline()
    names = {e.name for e in prof.events}
    assert {"trace.instantiate", "trace.simulate",
            "trace.timeline"} <= names


def test_spans_thread_safety():
    def work(i):
        with span(f"t{i}"):
            pass

    with profiled() as prof:
        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(prof.events) == 8


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_counter_gauge_histogram():
    c = obs_metrics.counter("c")
    c.inc()
    c.inc(4)
    assert obs_metrics.counter("c").value == 5
    g = obs_metrics.gauge("g")
    g.set(2.5)
    g.add(0.5)
    assert g.value == 3.0
    h = obs_metrics.histogram("h")
    for v in (1e-7, 1e-3, 5.0):
        h.observe(v)
    assert h.count == 3
    assert h.vmin == 1e-7 and h.vmax == 5.0
    assert h.mean == pytest.approx((1e-7 + 1e-3 + 5.0) / 3)
    assert sum(h.counts) == 3


def test_snapshot_merges_cache_stats_and_diff():
    obs_metrics.counter("evt").inc(2)
    a = snapshot()
    assert "caches" in a and "batched_stale_rewraps" in a["caches"]
    assert a["counters"]["evt"] == 2
    obs_metrics.counter("evt").inc(3)
    b = snapshot()
    d = diff(a, b)
    assert d["counter.evt"] == 3
    # nothing else ran between the two snapshots
    assert all(v == 0 for k, v in d.items() if k != "counter.evt")


def test_format_snapshot_and_diff_render():
    obs_metrics.counter("x").inc()
    s = obs_metrics.format_snapshot(snapshot(caches=False))
    assert "counter.x" in s
    assert obs_metrics.format_snapshot({"counters": {}}) \
        == "(no metrics recorded)"
    assert obs_metrics.format_diff({}) == "(no metric changed)"


# --------------------------------------------------------------------------
# logging
# --------------------------------------------------------------------------

def test_configure_idempotent_no_handler_stacking():
    root = obs_log.configure(force=True)
    n = len(root.handlers)
    obs_log.configure()
    obs_log.configure()
    assert len(root.handlers) == n
    assert root.propagate is False


def test_get_logger_namespacing():
    lg = obs_log.get_logger("core.dse")
    assert lg.name == "repro.core.dse"
    assert obs_log.get_logger().name == "repro"


def test_log_level_from_configure(capsys):
    import sys
    obs_log.configure("debug", stream=sys.stderr, force=True)
    try:
        obs_log.get_logger("test").debug("breadcrumb %d", 7)
        assert "repro.test: breadcrumb 7" in capsys.readouterr().err
    finally:
        obs_log.configure(force=True)   # back to env-derived default


def test_batched_fallback_breadcrumbs_and_counters():
    # the repro root logger does not propagate, so capture with our own
    # handler rather than caplog
    records = []

    class _Capture(logging.Handler):
        def emit(self, record):
            records.append(record.getMessage())

    lg = obs_log.get_logger("core.batched")
    handler = _Capture(level=logging.DEBUG)
    old_level = lg.level
    lg.addHandler(handler)
    lg.setLevel(logging.DEBUG)
    try:
        sc = (Scenario(SPEC).train(batch=32, seq=2048)
              .with_backend("batched"))
        res = sc.sweep(world=4, schedule="zb-h1")
    finally:
        lg.removeHandler(handler)
        lg.setLevel(old_level)
    assert len(res) > 0
    # pp>1 zb-h1 configs fell back with a logged reason + counter
    assert obs_metrics.counter("batched.fallback_schedule").value > 0
    assert any("non-replayable" in m for m in records)
    assert obs_metrics.counter("batched.kernel_calls").value > 0


# --------------------------------------------------------------------------
# cache taxonomy: evictions vs staleness re-wraps
# --------------------------------------------------------------------------

def test_batched_cache_counts_stale_rewrap_not_eviction():
    from repro.api import _batched_engines, _engines

    sc = Scenario(SPEC).train(batch=32, seq=2048)
    env = sc.env()
    before = (_batched_engines.stale_rewraps, _batched_engines.evictions)
    e1 = _batched_engines.engine(SPEC, "train", env)
    e2 = _batched_engines.engine(SPEC, "train", env)
    assert e2 is e1
    # invalidate ONLY the underlying compiled engine: the batched slot
    # for the key survives but wraps a dead engine
    with _engines._lock:
        _engines._store.clear()
    e3 = _batched_engines.engine(SPEC, "train", env)
    assert e3 is not e1
    assert e3.engine is _engines.engine(SPEC, "train", env)
    assert _batched_engines.stale_rewraps == before[0] + 1
    # regression: the re-wrap must NOT masquerade as LRU pressure
    assert _batched_engines.evictions == before[1]


def test_batched_cache_counts_real_eviction():
    from repro.api import _BatchedEngineCache

    env_a = Scenario(SPEC).train(batch=32, seq=2048).env()
    env_b = Scenario(SPEC).train(batch=64, seq=2048).env()
    cache = _BatchedEngineCache(maxsize=1)
    cache.engine(SPEC, "train", env_a)
    cache.engine(SPEC, "train", env_b)   # different key -> pushes env_a out
    assert cache.evictions == 1
    assert cache.stale_rewraps == 0
    assert cache.builds == 2


def test_compiled_cache_stats_new_keys():
    stats = compiled_cache_stats()
    for key in ("engines", "classes", "compiles", "hits",
                "batched_engines", "graph_builds", "graph_hits",
                "graph_evictions", "engine_builds", "engine_hits",
                "engine_evictions", "batched_builds", "batched_hits",
                "batched_evictions", "batched_stale_rewraps",
                "series_builds", "series_hits", "series_evictions",
                "series_regrows"):
        assert key in stats, key


# --------------------------------------------------------------------------
# sweep progress + summary telemetry
# --------------------------------------------------------------------------

def _collecting_cb(calls):
    def cb(done, total, skipped, eta):
        calls.append((done, total, skipped, eta))
    return cb


@pytest.mark.parametrize("kw", [
    {},                               # serial compiled
    {"workers": 4},                   # thread executor
], ids=["serial", "thread"])
def test_sweep_progress_callback(kw):
    calls = []
    res = (Scenario(SPEC).train(batch=32, seq=2048)
           .sweep(world=4, progress=_collecting_cb(calls), **kw))
    assert len(res) > 0
    done, total, skipped, eta = calls[-1]
    assert done == total == len(res) + len(res.skipped)
    assert skipped == len(res.skipped)
    assert eta == 0.0
    # done is monotone non-decreasing across callbacks
    dones = [c[0] for c in calls]
    assert dones == sorted(dones)
    # eta is None before the first completion, a float after
    assert all(e is None or e >= 0.0 for _, _, _, e in calls)


def test_sweep_progress_callback_batched():
    calls = []
    res = (Scenario(SPEC).train(batch=32, seq=2048).with_backend("batched")
           .sweep(world=4, progress=_collecting_cb(calls)))
    assert len(res) > 0
    assert calls[-1][0] == calls[-1][1] == len(res) + len(res.skipped)


def test_sweep_progress_counts_prefiltered_as_skipped():
    calls = []
    # microbatches=3 never divides a per-rank batch of 32/dp -> many
    # prefilter skips
    res = (Scenario(SPEC).train(batch=32, seq=2048)
           .sweep(world=4, microbatches=3,
                  progress=_collecting_cb(calls)))
    assert res.pruned     # something was prefiltered
    assert calls[-1][0] == calls[-1][1]
    assert calls[-1][2] == len(res.skipped)


def test_sweep_summary_telemetry_lines():
    res = (Scenario(SPEC).train(batch=32, seq=2048).with_backend("batched")
           .sweep(world=4))
    s = res.summary()
    assert "hit ratio" in s
    assert "kernel call(s)" in s and "batch sizes" in s


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_obs_cli_summarize_diff_validate(tmp_path, capsys):
    from repro.obs.__main__ import main

    obs_metrics.counter("cli.evt").inc(2)
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(snapshot(caches=False)))
    obs_metrics.counter("cli.evt").inc(5)
    b.write_text(json.dumps(snapshot(caches=False)))

    assert main(["summarize", str(a)]) == 0
    assert "counter.cli.evt" in capsys.readouterr().out
    assert main(["diff", str(a), str(b)]) == 0
    assert "+5" in capsys.readouterr().out

    tl = tmp_path / "tl.json"
    tr = (Scenario(SPEC).train(batch=32, seq=2048)
          .parallel(pp=2, tp=2, microbatches=4).trace())
    tr.timeline(str(tl))
    assert main(["validate", str(tl)]) == 0
    assert "OK" in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    obj = json.loads(tl.read_text())
    for ev in obj["traceEvents"]:
        if ev["ph"] == "X":
            ev["dur"] = -1.0          # invalid duration
            break
    bad.write_text(json.dumps(obj))
    assert main(["validate", str(bad)]) == 1
    assert "STG501" in capsys.readouterr().out
