"""Scenario/Trace fluent API tests: golden equivalence against the
legacy primitive pipeline, graph-cache reuse (the one-assembly-per-sweep
property), fluent semantics, and the deprecated generate() shim."""
import warnings

import pytest

import repro.api as api
from repro import ModelSpec, ParallelCfg, Scenario, TPU_V5E
from repro.core import (MoESpec, apply_pipeline, bind_env, build_graph,
                        distribute, generate, instantiate, peak_memory,
                        simulate, total_layers)

GPT = ModelSpec(name="gptish", n_layers=4, d_model=256, n_heads=8,
                n_kv_heads=4, d_ff=512, vocab=4096)
MOE = ModelSpec(name="moeish", n_layers=2, d_model=128, n_heads=4,
                n_kv_heads=4, d_ff=256, vocab=512,
                moe=MoESpec(8, 2, 2, 64))


def legacy_pipeline(spec, cfg, *, batch, seq, kv_len=None, mode="train"):
    """The pre-Scenario call sequence, from primitives (no caching)."""
    env = bind_env(spec, batch=batch, seq=seq, kv_len=kv_len)
    g = build_graph(spec, mode=mode).graph
    distribute(g, cfg, env)
    plan = apply_pipeline(g, cfg.pp, total_layers(spec))
    w = instantiate(g, cfg, env, plan, name=f"{spec.name}/{mode}")
    return w, g, plan, env


# ---- golden equivalence: new API == legacy path --------------------------

@pytest.mark.parametrize("spec,par,cfg", [
    (GPT,
     dict(dp=2, tp=2, sp=True, zero1=True),
     ParallelCfg(axes={"dp": 2, "tp": 2}, dp_axis="dp", tp_axis="tp",
                 sp=True, zero1=True)),
    (GPT,
     dict(dp=2, pp=2, microbatches=4, fsdp=True),
     ParallelCfg(axes={"dp": 2}, dp_axis="dp", fsdp=True, pp=2,
                 microbatches=4)),
    (MOE,
     dict(dp=4, ep=True),
     ParallelCfg(axes={"dp": 4}, dp_axis="dp", ep_axis="dp")),
], ids=["gpt-tp-sp-zero1", "gpt-pp-fsdp", "moe-ep"])
def test_trace_matches_legacy_train(spec, par, cfg):
    tr = Scenario(spec).train(batch=8, seq=64).parallel(**par).trace()
    w, g, plan, env = legacy_pipeline(spec, cfg, batch=8, seq=64)
    assert tr.op_counts() == w.op_counts()
    assert tr.comm_counts() == w.comm_counts()
    assert tr.comm_volume() == w.comm_volume()
    assert tr.total_flops() == w.total_flops()
    legacy_mem = peak_memory(g, cfg, env, plan)
    assert abs(tr.memory().peak_bytes - legacy_mem.peak_bytes) < 1e-6
    assert tr.simulate(TPU_V5E).step_time == simulate(w, TPU_V5E).step_time


def test_trace_matches_legacy_decode():
    tr = Scenario(GPT).decode(batch=4, kv_len=256).parallel(dp=2).trace()
    cfg = ParallelCfg(axes={"dp": 2}, dp_axis="dp")
    w, *_ = legacy_pipeline(GPT, cfg, batch=4, seq=1, kv_len=256,
                            mode="decode")
    assert tr.op_counts() == w.op_counts()
    assert tr.comm_counts() == w.comm_counts()
    assert tr.total_flops() == w.total_flops()


# ---- the sweep hot path: one symbolic assembly per mode ------------------

def test_sweep_assembles_graph_exactly_once(monkeypatch):
    real_build = api.build_graph
    calls = []

    def spy(spec, *, mode="train", **kw):
        calls.append((spec.name, mode))
        return real_build(spec, mode=mode, **kw)

    monkeypatch.setattr(api, "build_graph", spy)
    api.clear_graph_cache()
    pts = Scenario(GPT).train(batch=32, seq=64).sweep(
        world=16, max_tp=4, microbatches=2)
    assert len(pts) >= 16                 # a real sweep, not a toy
    assert calls == [("gptish", "train")]  # ONE assembly for all points
    stats = api.graph_cache_stats()
    assert stats["builds"] == 1
    api.clear_graph_cache()


def test_trace_reuses_cached_assembly(monkeypatch):
    real_build = api.build_graph
    calls = []

    def spy(spec, *, mode="train", **kw):
        calls.append(mode)
        return real_build(spec, mode=mode, **kw)

    monkeypatch.setattr(api, "build_graph", spy)
    api.clear_graph_cache()
    sc = Scenario(GPT).train(batch=8, seq=64)
    w1 = sc.parallel(dp=2).trace().workload
    w2 = sc.parallel(dp=2, fsdp=True).trace().workload
    assert len(calls) == 1                # second config hits the cache
    assert w1.comm_counts() != w2.comm_counts()   # but is distributed anew
    api.clear_graph_cache()


def test_traces_do_not_share_graphs():
    sc = Scenario(GPT).train(batch=8, seq=64).parallel(dp=2)
    t1, t2 = sc.trace(), sc.trace()
    assert t1.graph is not t2.graph
    uids = {op.uid for op in t1.graph.ops}
    assert uids.isdisjoint({op.uid for op in t2.graph.ops})


# ---- fluent semantics ----------------------------------------------------

def test_scenario_immutability():
    sc = Scenario(GPT)
    sc2 = sc.train(batch=8, seq=64)
    assert sc.batch == 1 and sc2.batch == 8
    with pytest.raises(AttributeError):
        sc.batch = 4                      # frozen dataclass


def test_serve_mode_inference():
    assert Scenario(GPT).serve(batch=4, kv_len=128).mode == "decode"
    assert Scenario(GPT).serve(batch=4, seq=128).mode == "prefill"
    assert Scenario(GPT).decode(batch=4, kv_len=64).kv_len == 64
    with pytest.raises(ValueError):
        Scenario(GPT, mode="bogus")


def test_parallel_builds_mesh():
    cfg = Scenario(GPT).parallel(dp=4, tp=2, cp=2, pp=2, fsdp=True,
                                 zero1=True).cfg
    assert cfg.axes == {"dp": 4, "tp": 2, "cp": 2}
    assert (cfg.dp_axis, cfg.tp_axis, cfg.cp_axis) == ("dp", "tp", "cp")
    assert cfg.sp                          # SP defaults on with TP
    assert cfg.fsdp and cfg.zero1 and cfg.pp == 2
    assert cfg.world == 32


def test_parallel_degrades_degenerate_axes():
    cfg = Scenario(GPT).parallel(tp=4, fsdp=True, zero1=True, ep=True).cfg
    assert cfg.dp_axis is None and not cfg.fsdp and not cfg.zero1
    assert cfg.ep_axis is None
    assert Scenario(GPT).parallel(tp=4, sp=False).cfg.sp is False
    assert Scenario(MOE).parallel(tp=4, ep="tp").cfg.ep_axis == "tp"


def test_trace_is_lazy_and_memoized():
    tr = Scenario(GPT).train(batch=8, seq=64).parallel(dp=2).trace()
    assert tr._workload is None            # nothing ran yet
    w = tr.workload
    assert tr.workload is w                # memoized
    assert tr.graph is tr.graph
    assert tr.simulate(TPU_V5E) is tr.simulate(TPU_V5E)
    assert tr.memory() is tr.memory()
    assert tr.memory(recompute=True) is not tr.memory()


def test_summary_shape():
    s = (Scenario(GPT).train(batch=8, seq=64).parallel(dp=2, tp=2)
         .trace().summary(TPU_V5E))
    assert set(s) >= {"scenario", "hw", "world", "step_ms", "peak_gb",
                      "overlap"}
    assert s["world"] == 4 and s["step_ms"] > 0


# ---- deprecated shim -----------------------------------------------------

def test_generate_shim_warns_and_matches():
    cfg = ParallelCfg(axes={"dp": 2}, dp_axis="dp")
    with pytest.warns(DeprecationWarning):
        w, g, plan, env = generate(GPT, cfg, batch=8, seq=64)
    tr = Scenario(GPT).train(batch=8, seq=64).parallel(dp=2).trace()
    assert w.op_counts() == tr.op_counts()
    assert w.comm_counts() == tr.comm_counts()
    assert plan.pp == 1 and env is not None and g.ops


# ---- satellite regression: einsum out_shape_hint -------------------------

def test_einsum_out_shape_hint_threaded():
    from repro.core.stg import GraphBuilder
    from repro.core.symbolic import sym
    b = GraphBuilder()
    x = b.input("x", (sym("B"), sym("H")))
    w = b.weight("w", (sym("H"),))
    # output letter 'k' appears in no input: only the hint can bind it
    out = b.einsum("proj", "bh,h->bk", [x, w],
                   out_shape_hint={"b": sym("B"), "k": sym("K")})
    assert out.shape == (sym("B"), sym("K"))


# ---- engine cache LRU bounds and batched staleness guard -----------------

def _env_for(batch):
    return Scenario(GPT).train(batch=batch, seq=64).env()


def test_engine_cache_lru_eviction():
    """The compiled-engine cache is LRU-bounded at maxsize: the oldest
    binding falls out and is rebuilt on re-request; a recent one is
    returned identically."""
    api.clear_graph_cache()
    n = api._engines.maxsize
    engines = {b: api._engines.engine(GPT, "train", _env_for(b))
               for b in range(1, n + 3)}       # n+2 distinct env keys
    assert len(api._engines._store) == n
    # most recent still cached (same object) ...
    assert api._engines.engine(GPT, "train", _env_for(n + 2)) \
        is engines[n + 2]
    # ... but the two oldest were evicted and come back as new objects
    assert api._engines.engine(GPT, "train", _env_for(1)) is not engines[1]
    api.clear_graph_cache()


def test_batched_engine_cache_eviction_and_staleness():
    """The batched cache is LRU-bounded too, and a hit is honoured only
    while it still wraps the live compiled engine for the same key — if
    the base was evicted and rebuilt, the stale wrapper is replaced
    (its jitted kernels would otherwise pin dead structure classes)."""
    api.clear_graph_cache()
    n = api._batched_engines.maxsize
    first = api._batched_engines.engine(GPT, "train", _env_for(1))
    assert first.engine is api._engines.engine(GPT, "train", _env_for(1))
    # same key -> same wrapper while the base engine is alive
    assert api._batched_engines.engine(GPT, "train", _env_for(1)) is first
    # push the base (and wrapper) out of both LRUs
    for b in range(2, api._engines.maxsize + 3):
        api._batched_engines.engine(GPT, "train", _env_for(b))
    assert len(api._batched_engines._store) == n
    rebuilt = api._batched_engines.engine(GPT, "train", _env_for(1))
    assert rebuilt is not first
    assert rebuilt.engine is api._engines.engine(GPT, "train", _env_for(1))
    assert rebuilt.engine is not first.engine
    api.clear_graph_cache()


def test_clear_graph_cache_clears_batched():
    api._batched_engines.engine(GPT, "train", _env_for(4))
    assert api.compiled_cache_stats()["batched_engines"] >= 1
    api.clear_graph_cache()
    stats = api.compiled_cache_stats()
    assert stats["engines"] == 0 and stats["batched_engines"] == 0
