"""Checkpoint I/O hardening (typed errors, manifest validation, step-0
guard, keep-N rotation, view-dtype roundtrips, elastic restore) and the
straggler watchdog's evict/decay bookkeeping."""
import json
import os

import jax
import ml_dtypes
import numpy as np
import pytest

from repro.ckpt import (CheckpointError, CheckpointManager,
                        ManifestMismatchError, TemplateMismatchError,
                        latest_step, restore, save)
from repro.ft import StragglerModel, StragglerWatchdog, drive_watchdog, \
    elastic_mesh_shape, shrink_cfg
from repro.models.common import Param


def _state(dtype=np.float32):
    return {
        "layers": [
            {"w": Param(np.arange(12, dtype=dtype).reshape(3, 4),
                        ("d_model", "d_ff")),
             "b": Param(np.zeros(4, dtype=dtype), ("d_ff",))},
        ],
        "step_marker": np.asarray(7, dtype=np.int32),
        "frozen": None,
    }


def _leaves(state):
    out = []

    def rec(node):
        if isinstance(node, Param):
            out.append(np.asarray(node.value))
        elif isinstance(node, dict):
            for k in sorted(node):
                rec(node[k])
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        elif node is not None:
            out.append(np.asarray(node))
    rec(state)
    return out


# ---- roundtrips -----------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float8_e4m3fn",
                                   "float8_e5m2"])
def test_save_restore_roundtrip_dtypes(tmp_path, dtype):
    np_dtype = getattr(ml_dtypes, dtype) if dtype != "float32" \
        else np.float32
    state = _state(np_dtype)
    save(str(tmp_path), 5, state)
    restored, step = restore(str(tmp_path), state)
    assert step == 5
    for a, b in zip(_leaves(state), _leaves(restored)):
        assert a.dtype == b.dtype          # view dtypes survive npz
        np.testing.assert_array_equal(
            a.view(np.uint8) if a.dtype != np.int32 else a,
            b.view(np.uint8) if b.dtype != np.int32 else b)


def test_restore_with_shardings_device_put(tmp_path):
    state = _state()
    save(str(tmp_path), 1, state)
    dev = jax.devices()[0]
    shardings = {"layers": [{"w": dev, "b": dev}],
                 "step_marker": dev, "frozen": None}
    restored, _ = restore(str(tmp_path), state, shardings=shardings)
    assert isinstance(restored["layers"][0]["w"], Param)


def test_elastic_restore_smaller_mesh(tmp_path):
    """The checkpoint stores logical axes, not device ids: state written
    under one parallel config restores under a shrunken one (the
    elastic path after an eviction)."""
    from repro import ParallelCfg
    cfg = ParallelCfg(axes={"dp": 4, "tp": 2}, dp_axis="dp", tp_axis="tp",
                      sp=True, pp=2)
    state = _state()
    save(str(tmp_path), 10, state, n_hosts=cfg.world // 8 or 1)
    small = shrink_cfg(cfg, 8)             # dp 4 -> 2, model mesh intact
    assert small.world == 8
    restored, step = restore(str(tmp_path), state)
    assert step == 10
    np.testing.assert_array_equal(
        np.asarray(restored["layers"][0]["w"].value),
        np.asarray(state["layers"][0]["w"].value))
    assert restored["layers"][0]["w"].axes == ("d_model", "d_ff")
    assert elastic_mesh_shape(small.world, model=4) == (2, 4)


# ---- typed errors ---------------------------------------------------------

def test_template_mismatch_is_typed_with_path(tmp_path):
    state = _state()
    save(str(tmp_path), 2, state)
    bigger = dict(state)
    bigger["extra"] = Param(np.ones(2, dtype=np.float32), ("d",))
    with pytest.raises(TemplateMismatchError) as ei:
        restore(str(tmp_path), bigger)
    assert ei.value.path == "/extra"
    assert isinstance(ei.value, CheckpointError)
    assert "/extra" in str(ei.value)


def test_manifest_dtype_mismatch_rejected(tmp_path):
    state = _state()
    d = save(str(tmp_path), 3, state)
    mpath = os.path.join(d, "manifest.json")
    man = json.load(open(mpath))
    ent = next(e for e in man["entries"] if e["path"].endswith("/w"))
    ent["dtype"] = "float64"
    json.dump(man, open(mpath, "w"))
    with pytest.raises(ManifestMismatchError) as ei:
        restore(str(tmp_path), state)
    assert ei.value.path == ent["path"]
    assert "float64" in str(ei.value)


def test_manifest_shape_mismatch_rejected(tmp_path):
    state = _state()
    d = save(str(tmp_path), 3, state)
    mpath = os.path.join(d, "manifest.json")
    man = json.load(open(mpath))
    ent = next(e for e in man["entries"] if e["path"].endswith("/w"))
    ent["shape"] = [4, 3]
    json.dump(man, open(mpath, "w"))
    with pytest.raises(ManifestMismatchError, match="shape"):
        restore(str(tmp_path), state)


# ---- manager policy -------------------------------------------------------

def test_maybe_save_skips_step_zero(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=10)
    state = _state()
    assert mgr.maybe_save(0, state) is None          # init state: no ckpt
    assert latest_step(str(tmp_path)) is None
    assert mgr.maybe_save(5, state) is None          # off-cadence
    assert mgr.maybe_save(10, state) is not None


def test_keep_n_rotation_order(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, state)
    steps = sorted(int(f.split("_")[1]) for f in os.listdir(tmp_path))
    assert steps == [3, 4]
    restored, step = mgr.resume(state)
    assert step == 4 and restored is not None


# ---- watchdog -------------------------------------------------------------

def _hosts(slow, n=4, mult=3.0):
    return {f"h{i}": (mult if f"h{i}" == slow else 1.0) for i in range(n)}


def test_watchdog_evict_decrements_world_and_clears_strikes():
    wd = StragglerWatchdog(n_hosts=4, threshold=1.5, max_strikes=2)
    wd.observe(1.0)                                  # settle EMA
    per = {h: m * 1.0 for h, m in _hosts("h2").items()}
    assert wd.observe(3.0, per_host=per).kind == "warn"
    d = wd.observe(3.0, per_host=per)
    assert d.kind == "evict" and d.hosts == ("h2",)
    assert d.new_world == 3 and wd.n_hosts == 3      # world shrank
    assert "h2" not in wd.strikes                    # history gone
    # a second straggler evicts against the SHRUNKEN world
    per = {h: m * 1.0 for h, m in _hosts("h1", n=3).items()}
    wd.observe(1.0)
    for _ in range(4):
        d = wd.observe(3.0, per_host=per)
        if d.kind == "evict":
            break
    assert d.kind == "evict" and d.new_world == 2


def test_watchdog_strikes_decay_on_healthy_steps():
    wd = StragglerWatchdog(n_hosts=4, threshold=1.5, max_strikes=3,
                           strike_decay=0.5)
    wd.observe(1.0)
    per = {h: m * 1.0 for h, m in _hosts("h0").items()}
    wd.observe(3.0, per_host=per)
    assert wd.strikes["h0"] == 1
    wd.observe(1.0)                                  # healthy: 1 -> 0.5
    assert wd.strikes["h0"] == 0.5
    wd.observe(1.0)                                  # 0.25 < 0.5: dropped
    assert "h0" not in wd.strikes
    # transient blips never reach max_strikes when spaced by healthy
    # steps; a persistent straggler still gets evicted
    for _ in range(6):
        wd.observe(3.0, per_host=per)
        d = wd.observe(1.0)
    assert wd.n_hosts == 4 and d.kind == "ok"


def test_drive_watchdog_detects_injected_straggler():
    wd = StragglerWatchdog(n_hosts=4, threshold=1.5, max_strikes=2)
    decisions = drive_watchdog(wd, healthy_step=1.0,
                               host_mults={"h0": 1.0, "h1": 2.5,
                                           "h2": 1.0, "h3": 1.0},
                               warmup=3, steps=10)
    kinds = [d.kind for d in decisions]
    assert kinds[:3] == ["ok", "ok", "ok"]
    ev = next(d for d in decisions if d.kind == "evict")
    assert ev.hosts == ("h1",) and ev.new_world == 3
    # after the eviction the remaining fleet is healthy
    assert decisions[-1].kind == "ok"


def test_straggler_model_host_view_feeds_watchdog():
    sm = StragglerModel(slow_fraction=0.0, slowdown=4.0, seed=0)
    mults = dict(sm.host_multipliers(32, ranks_per_host=8))
    assert set(mults) == {0, 1, 2, 3}
    assert all(m == 1.0 for m in mults.values())     # nobody straggles
    wd = StragglerWatchdog(n_hosts=4, threshold=1.5, max_strikes=2)
    assert all(d.kind == "ok" for d in
               drive_watchdog(wd, 1.0, mults, warmup=2, steps=5))
