"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU; shapes + finiteness asserted (assignment
requirement (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get
from repro.models import (RuntimeCfg, decode_step, init_cache, init_params,
                          loss_fn)

RT = RuntimeCfg(attention_impl="chunked", attn_chunk=64)


def _batch(spec, B=2, S=32):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, spec.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if spec.encoder_layers:
        batch["frames"] = jnp.ones((B, spec.enc_seq, spec.d_model),
                                   jnp.bfloat16)
    if spec.vision_seq:
        batch["vision"] = jnp.ones((B, spec.vision_seq, spec.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    arch = get(name)
    spec = arch.smoke
    params = init_params(spec, RT, jax.random.PRNGKey(0))
    batch = _batch(spec)

    def step(p, b):
        l, g = jax.value_and_grad(lambda pp: loss_fn(pp, b, spec, RT))(p)
        return l, g

    loss, grads = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{name}: non-finite grads"


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_step(name):
    arch = get(name)
    spec = arch.smoke
    params = init_params(spec, RT, jax.random.PRNGKey(0))
    cache = init_cache(spec, RT, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, c, t, spec, RT))(params, cache, tok)
    assert logits.shape == (2, 1, spec.vocab)
    assert bool(jnp.isfinite(logits).all()), name
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_full_configs_match_assignment():
    """The full-size SPEC fields must equal the assigned table exactly."""
    expect = {
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for name, (L, H, NH, NKV, DFF, V) in expect.items():
        s = get(name).spec
        assert s.n_layers == L and s.d_model == H, name
        assert s.n_heads == NH and s.n_kv_heads == NKV, name
        assert s.vocab == V, name
        if DFF is not None:
            assert s.d_ff == DFF, name
    # MoE widths per assignment
    assert get("deepseek-moe-16b").spec.moe.d_expert == 1408
    assert get("deepseek-moe-16b").spec.moe.n_experts == 64
    assert get("deepseek-moe-16b").spec.moe.top_k == 6
    assert get("deepseek-v2-236b").spec.moe.d_expert == 1536
    assert get("deepseek-v2-236b").spec.moe.n_experts == 160
    assert get("deepseek-v2-236b").spec.mla.kv_lora == 512
    assert get("jamba-v0.1-52b").spec.moe.n_experts == 16
    assert get("jamba-v0.1-52b").spec.moe.top_k == 2


def test_long_500k_applicability():
    runs = {a for a in ARCHS if "long_500k" not in get(a).skip}
    assert runs == {"rwkv6-7b", "jamba-v0.1-52b", "gemma2-27b"}
