"""Collective Communication Matcher unit tests (paper Table IV) +
hypothesis property sweep over arbitrary producer/consumer layouts."""
import pytest

from hypothesis_compat import given, settings, st

from repro.core.matcher import CommStep, MatchError, _apply_step, _canon, match
from repro.core.tensor import ShardSpec


def steps(p, d):
    return [(s.coll, s.axis, s.dim, s.dim_dst) for s in match(p, d)]


# ---- the exact rows of paper Table IV (tensor [B, S, H]) -----------------
# producer: [B/dp, S, H@1/tp]
P = ShardSpec.make({0: ("dp",)}, partial=("tp",))


def test_reducescatter():
    # -> [B/dp, S, H/tp]
    want = ShardSpec.make({0: ("dp",), 2: ("tp",)})
    assert steps(P, want) == [("ReduceScatter", "tp", 2, None)]


def test_alltoall():
    # -> [B, S/dp, H@1/tp]   (dp moves batch->seq; tp partial untouched)
    want = ShardSpec.make({1: ("dp",)}, partial=("tp",))
    assert steps(P, want) == [("AllToAll", "dp", 0, 1)]


def test_allgather():
    # -> [B, S, H@1/tp]
    want = ShardSpec.make({}, partial=("tp",))
    assert steps(P, want) == [("AllGather", "dp", 0, None)]


def test_allreduce():
    # -> [B/dp, S, H]
    want = ShardSpec.make({0: ("dp",)})
    assert steps(P, want) == [("AllReduce", "tp", None, None)]


def test_reducescatter_plus_alltoall():
    # -> [B/tp, S, H/dp]
    want = ShardSpec.make({0: ("tp",), 2: ("dp",)})
    got = steps(P, want)
    assert got == [("ReduceScatter", "tp", 0, None), ("AllToAll", "dp", 0, 2)]


def test_allreduce_plus_allgather():
    # -> [B, S, H]
    want = ShardSpec()
    got = steps(P, want)
    assert ("AllReduce", "tp", None, None) in got
    assert ("AllGather", "dp", 0, None) in got
    assert len(got) == 2


def test_slice_is_local():
    got = steps(ShardSpec(), ShardSpec.make({1: ("tp",)}))
    assert got == [("Slice", "tp", 1, None)]


def test_noop():
    assert steps(P, P) == []


def test_push_partialsum_rejected():
    with pytest.raises(MatchError):
        match(ShardSpec(), ShardSpec.make({}, partial=("tp",)))


# ---- property: matcher always lands exactly on the consumer layout -------
AXES = ("dp", "tp", "cp")


@st.composite
def shard_specs(draw, rank=3, allow_partial=True):
    part = {}
    partial = []
    for ax in AXES:
        mode = draw(st.integers(0, 4 if allow_partial else 3))
        if mode == 4:
            partial.append(ax)
        elif mode > 0:
            part.setdefault(draw(st.integers(0, rank - 1)), []).append(ax)
    return ShardSpec.make({k: tuple(v) for k, v in part.items()},
                          tuple(partial))


@given(shard_specs(), shard_specs(allow_partial=False))
@settings(max_examples=300, deadline=None)
def test_match_reaches_consumer(prod, cons):
    cur = prod
    for step in match(prod, cons):
        cur = _apply_step(cur, step)
    assert _canon(cur) == _canon(cons)


@given(shard_specs(), shard_specs(allow_partial=False))
@settings(max_examples=300, deadline=None)
def test_match_step_count_bounded(prod, cons):
    # at most one collective per mesh axis + one local slice per axis
    assert len(match(prod, cons)) <= 2 * len(AXES)


@given(shard_specs())
@settings(max_examples=100, deadline=None)
def test_match_identity_is_empty(spec):
    assert match(spec, spec) == []
