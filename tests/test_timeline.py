"""Timeline-export tests: exact reconciliation of the Perfetto export
against ``SimResult.step_time`` for every bundled arch, train and serve,
across all four pipeline schedules; Chrome-trace schema validation;
serving pool lanes; resilience epoch tracks; and the STG5xx audit's
ability to catch corrupted exports."""
import json

import pytest

from repro import Scenario
from repro.analysis import check_timeline, check_timeline_file
from repro.configs import ARCHS, get
from repro.obs.timeline import validate_chrome_trace

SCHEDULES = ("gpipe", "1f1b", "zb-h1", "interleaved")


def _trace(name, mode, backend="compiled"):
    spec = get(name).smoke
    sc = Scenario(spec)
    if mode == "train":
        sc = sc.train(batch=32, seq=2048)
    else:
        sc = sc.serve(batch=8, seq=512)          # prefill
    return (sc.with_backend(backend)
            .parallel(pp=4, tp=2, microbatches=8).trace())


# --------------------------------------------------------------------------
# exact reconciliation: all archs x modes x schedules
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["train", "serve"])
@pytest.mark.parametrize("name", ARCHS)
def test_reconcile_exact_all_schedules(name, mode):
    tr = _trace(name, mode)
    for sched in SCHEDULES:
        sim = tr.simulate(schedule=sched)
        tl = tr.timeline(schedule=sched)
        # the invariant: per-track span sums tile [0, step_time] with
        # float-EXACT equality, because timeline events carry the same
        # float arithmetic the simulator used
        assert tl.reconcile(sim.step_time) == [], (name, mode, sched)
        assert tl.end_time == sim.step_time, (name, mode, sched)


@pytest.mark.parametrize("name", ARCHS[:2])
def test_reconcile_exact_sympy_backend(name):
    tr = _trace(name, "train", backend="sympy")
    for sched in SCHEDULES:
        sim = tr.simulate(schedule=sched)
        tl = tr.timeline(schedule=sched)
        assert tl.reconcile(sim.step_time) == []
        assert tl.end_time == sim.step_time


def test_reconcile_detects_mismatch():
    tr = _trace(ARCHS[0], "train")
    tl = tr.timeline()
    sim = tr.simulate()
    assert tl.reconcile(sim.step_time * 1.01) != []


# --------------------------------------------------------------------------
# Chrome-trace schema + audit
# --------------------------------------------------------------------------

def test_chrome_trace_schema_validates():
    tr = _trace(ARCHS[0], "train")
    obj = json.loads(json.dumps(tr.timeline().chrome_trace()))
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"]["kind"] == "simulated-execution"
    assert obj["otherData"]["step_time_s"] == tr.simulate().step_time
    # one named track per pipeline stage
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"stage 0", "stage 1", "stage 2", "stage 3"} <= names


def test_comm_spans_annotated():
    tr = _trace(ARCHS[0], "train")
    obj = tr.timeline().chrome_trace()
    comm = [e for e in obj["traceEvents"]
            if e["ph"] == "X" and e.get("cat") == "comm"]
    assert comm
    for e in comm:
        assert "coll" in e["args"] and "bytes" in e["args"], e["name"]


def test_timeline_save_and_file_audit(tmp_path):
    tr = _trace(ARCHS[0], "train")
    path = tmp_path / "tl.json"
    tr.timeline(str(path), schedule="1f1b")
    rep = check_timeline_file(str(path))
    assert rep.ok, rep.render()


def test_utilization_report():
    tr = _trace(ARCHS[0], "train")
    rep = tr.timeline().utilization()
    assert 0.0 < rep.mfu < 1.0
    assert 0.0 <= rep.bubble_fraction < 1.0
    assert 0.0 <= rep.exposed_comm_fraction <= 1.0
    assert "MFU" in rep.summary()


def test_memory_counters_exported():
    tr = _trace(ARCHS[0], "train")
    obj = tr.timeline(memory=True).chrome_trace()
    assert any(e["ph"] == "C" for e in obj["traceEvents"])


# --------------------------------------------------------------------------
# STG5xx: the audit catches corrupted exports
# --------------------------------------------------------------------------

def _corrupt(obj, fn):
    obj = json.loads(json.dumps(obj))
    fn(obj)
    return obj


@pytest.fixture(scope="module")
def train_trace_json():
    return _trace(ARCHS[0], "train").timeline().chrome_trace()


def test_stg501_schema_violation(train_trace_json):
    def negative_dur(obj):
        next(e for e in obj["traceEvents"] if e["ph"] == "X")["dur"] = -1.0
    rep = check_timeline(_corrupt(train_trace_json, negative_dur))
    assert "STG501" in rep.codes()
    assert not rep.ok


def test_stg502_tiling_gap(train_trace_json):
    def shift(obj):
        xs = [e for e in obj["traceEvents"]
              if e["ph"] == "X" and e["pid"] == 0 and e["tid"] == 0]
        xs.sort(key=lambda e: e["ts"])
        ev = next(e for e in xs[:-1] if e["dur"] > 1.0)
        ev["dur"] *= 0.5        # end recedes: a gap before the next span
    rep = check_timeline(_corrupt(train_trace_json, shift))
    assert "STG502" in rep.codes()


def test_stg503_step_time_mismatch(train_trace_json):
    def inflate(obj):
        obj["otherData"]["step_time_s"] *= 2.0
    rep = check_timeline(_corrupt(train_trace_json, inflate))
    assert "STG503" in rep.codes()


def test_stg504_missing_comm_attrs(train_trace_json):
    def strip(obj):
        ev = next(e for e in obj["traceEvents"]
                  if e["ph"] == "X" and e.get("cat") == "comm")
        del ev["args"]["bytes"]
    rep = check_timeline(_corrupt(train_trace_json, strip))
    assert "STG504" in rep.codes()


def test_clean_export_audits_clean(train_trace_json):
    rep = check_timeline(train_trace_json)
    assert rep.ok and rep.codes() == set()


# --------------------------------------------------------------------------
# resilience epochs
# --------------------------------------------------------------------------

def _resilience_timeline():
    spec = get(ARCHS[0]).smoke
    sc = (Scenario(spec).train(batch=32, seq=2048)
          .resilience(mtbf=300.0, seed=3))
    tr = sc.parallel(pp=4, tp=2, microbatches=8).trace()
    return tr.timeline(resilience=sc.resilience_spec, resilience_steps=2000)


def test_resilience_track_epochs_ordered():
    tl = _resilience_timeline()
    obj = tl.chrome_trace()
    marks = [e for e in obj["traceEvents"]
             if e["ph"] == "X" and e.get("cat") == "resilience"]
    assert marks, "small MTBF must sample failures over 2000 steps"
    fails = sorted((e for e in marks if e["args"]["kind"] == "failure"),
                   key=lambda e: e["ts"])
    rests = sorted((e for e in marks if e["args"]["kind"] == "restore"),
                   key=lambda e: e["ts"])
    # the same invariants STG401-404 enforce on exported traces:
    # epochs number 0..n-1 in time order, failure/restore alternate,
    # each pair agrees on epoch + checkpoint step
    assert [f["args"]["epoch"] for f in fails] == list(range(len(fails)))
    assert len(rests) == len(fails)
    for i, (f, r) in enumerate(zip(fails, rests)):
        assert r["args"]["epoch"] == f["args"]["epoch"] == i
        assert r["args"]["ckpt_step"] == f["args"]["ckpt_step"]
        assert r["ts"] >= f["ts"]
    assert check_timeline(obj).ok


def test_stg505_epoch_order_violation():
    obj = _resilience_timeline().chrome_trace()
    fails = [e for e in obj["traceEvents"]
             if e.get("cat") == "resilience"
             and e["args"]["kind"] == "failure"]
    assert len(fails) >= 2
    fails[0]["args"]["epoch"], fails[1]["args"]["epoch"] = \
        fails[1]["args"]["epoch"], fails[0]["args"]["epoch"]
    rep = check_timeline(json.loads(json.dumps(obj)))
    assert "STG505" in rep.codes()


# --------------------------------------------------------------------------
# serving job timelines: pool lanes
# --------------------------------------------------------------------------

def test_job_timeline_pool_lanes(tmp_path):
    spec = get("minitron-8b").smoke
    job = (Scenario(spec).generation(out_tokens=32, batch=8, seq=256)
           .disaggregate(prefill_pool=dict(tp=2),
                         decode_pool=dict(tp=1),
                         kv_transfer=True))
    res = job.evaluate()
    tl = job.timeline(str(tmp_path / "job.json"))
    obj = json.loads((tmp_path / "job.json").read_text())
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"]["kind"] == "serving-job"
    assert obj["otherData"]["total_time_s"] == res.total_time
    lanes = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert "pool prefill" in lanes and "pool decode" in lanes
    assert "pool kv-transfer" in lanes
    kv = [e for e in obj["traceEvents"]
          if e["ph"] == "X" and e.get("cat") == "comm"]
    assert any(e["args"].get("coll") == "KVTransfer" for e in kv)
    assert check_timeline_file(str(tmp_path / "job.json")).ok
