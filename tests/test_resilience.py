"""Resilience subsystem: failure models, closed-form goodput vs seeded
Monte Carlo, Young-Daly optimality, straggler perturbation (backend
parity), elastic re-shard costing, resilience-aware DSE ranking, and
Chakra failure/restore stamping with the STG4xx trace checks."""
import json
import math

import pytest

import repro.configs as configs
from repro import Scenario, TPU_V5E
from repro.analysis import check_trace, check_trace_dir
from repro.core.dse import DSEPoint, rank_points, score_resilience
from repro.core.topology import h100_hgx_pod, tpu_v5e_pod
from repro.ft import (CkptTier, FailureModel, ResilienceSpec, StragglerModel,
                      elastic_reshard, expected_goodput, overhead_curve,
                      peer_goodput, replay_goodput, score_point, shrink_cfg,
                      state_bytes, young_daly_interval)

SMOKE = configs.get("granite-34b").smoke
POD = h100_hgx_pod(2, node_mtbf=40e3)
# deliberately slow tier: large C/R amplify the storage-vs-peer
# asymmetry so ranking flips are unambiguous on tiny smoke state
SLOW = CkptTier("slow_fs", write_bw=1e4, read_bw=1e4, restart_latency=30.0)


def _scenario(**par):
    return (Scenario(SMOKE).train(batch=16, seq=256).cluster(POD)
            .parallel(**par))


# ---- failure model --------------------------------------------------------

def test_failure_model_superposition_and_attribution():
    m = ResilienceSpec(mtbf={"chip": 30e3, "nvlink": 50e3}) \
        .failure_model(POD, 16)
    names = {d.name: d for d in m.domains}
    assert names["chip"].units == 16 and names["chip"].ranks_lost == 1
    assert names["nvlink"].units == 2 and names["nvlink"].ranks_lost == 8
    assert m.rate == pytest.approx(16 / 30e3 + 2 / 50e3)
    assert m.system_mtbf == pytest.approx(1 / m.rate)
    tr = m.sample(200 * m.system_mtbf, seed=0)
    assert len(tr.events) > 100
    assert list(tr.times()) == sorted(tr.times())
    assert {e.domain for e in tr.events} == {"chip", "nvlink"}
    # deterministic in the seed, different across seeds
    assert m.sample(1e5, seed=3).times() == m.sample(1e5, seed=3).times()
    assert m.sample(1e5, seed=3).times() != m.sample(1e5, seed=4).times()


def test_tier_mtbf_annotations_via_factories():
    pod = h100_hgx_pod(4, node_mtbf=1e5, rail_mtbf=2e5)
    by = {t.name: t.mtbf for t in pod.tiers}
    assert by == {"nvlink": 1e5, "ib": 2e5}
    tpu = tpu_v5e_pod(2, slice_mtbf=5e4)
    assert [t.mtbf for t in tpu.tiers] == [5e4, None]
    with pytest.raises(ValueError, match="mtbf"):
        h100_hgx_pod(2, node_mtbf=-1.0)
    with pytest.raises(ValueError, match="unknown tiers"):
        ResilienceSpec(mtbf={"nope": 1e4}).failure_model(POD, 16)


# ---- closed form vs Monte Carlo (acceptance: <2% on 3 archs) --------------

@pytest.mark.parametrize("arch", ["granite-34b", "gemma2-27b", "qwen3-14b"])
def test_closed_form_goodput_matches_monte_carlo(arch):
    sc = (Scenario(configs.get(arch).smoke).train(batch=8, seq=128)
          .cluster(POD).parallel(dp=2, tp=2, pp=2, microbatches=4,
                                 fsdp=True))
    tr = sc.trace()
    spec = ResilienceSpec(mtbf={"chip": 20e3, "nvlink": 40e3}, ckpt=SLOW,
                          recovery="storage")
    hw = sc._effective_hw(TPU_V5E)
    rep = score_point(sc.cfg, tr.simulate(hw), tr.memory(), spec, hw)
    assert rep.recovery == "storage" and 0 < rep.goodput < 1
    model = spec.failure_model(POD, sc.cfg.world)
    trace = model.sample(3000 * model.system_mtbf, seed=spec.seed)
    mc = replay_goodput(trace, rep.interval, rep.ckpt_cost, rep.restore_cost)
    assert len(mc.events) > 1000
    assert mc.goodput == pytest.approx(rep.goodput, rel=0.02)


def test_young_daly_is_argmin_of_sampled_overhead_curve():
    sc = _scenario(tp=4, pp=4, microbatches=8)
    tr = sc.trace()
    spec = ResilienceSpec(mtbf={"chip": 20e3}, ckpt=SLOW)
    hw = sc._effective_hw(TPU_V5E)
    rep = score_point(sc.cfg, tr.simulate(hw), tr.memory(), spec, hw)
    i_yd = rep.interval
    assert i_yd == pytest.approx(
        young_daly_interval(rep.ckpt_cost, rep.system_mtbf))
    model = spec.failure_model(POD, sc.cfg.world)
    # ONE shared trace for every candidate: common random numbers make
    # the sampled argmin a low-variance estimate of the true optimum
    trace = model.sample(2000 * model.system_mtbf, seed=1)
    cands = [f * i_yd for f in (0.25, 0.5, 1.0, 2.0, 4.0)]
    curve = overhead_curve(trace, cands, rep.ckpt_cost, rep.restore_cost)
    best = min(curve, key=lambda kv: kv[1])[0]
    assert best == pytest.approx(i_yd)


def test_goodput_closed_form_degenerate_cases():
    assert expected_goodput(100.0, rate=0.0, ckpt_cost_s=10.0,
                            restore_cost_s=50.0) == pytest.approx(100 / 110)
    assert peer_goodput(0.0, 100.0) == 1.0
    assert young_daly_interval(10.0, math.inf) == math.inf
    with pytest.raises(ValueError):
        expected_goodput(0.0, rate=1e-3, ckpt_cost_s=1.0, restore_cost_s=1.0)
    with pytest.raises(ValueError):
        ResilienceSpec(mtbf={})
    with pytest.raises(ValueError, match="recovery"):
        ResilienceSpec(mtbf=1e4, recovery="magic")


# ---- straggler perturbation (parity by construction) ----------------------

def test_straggler_perturbation_backend_parity():
    sm = StragglerModel(slow_fraction=0.3, slowdown=1.8, seed=3)
    times = {}
    for backend in ("compiled", "sympy"):
        tr = (_scenario(dp=2, tp=2, pp=2, microbatches=4)
              .with_backend(backend).trace())
        base = tr.simulate()
        slow = tr.simulate(perturb=sm)
        ident = tr.simulate(perturb=(1.0, 1.0))
        assert ident.step_time == base.step_time      # bit-identical
        assert slow.step_time > base.step_time
        times[backend] = (base.step_time, slow.step_time)
    assert times["compiled"] == times["sympy"]


def test_straggler_model_determinism_and_stage_max():
    sm = StragglerModel(slow_fraction=0.5, slowdown=2.0, seed=7)
    assert sm.multipliers(16) == sm.multipliers(16)
    assert set(sm.multipliers(64)) == {1.0, 2.0}
    cfg = _scenario(dp=2, tp=2, pp=2, microbatches=4).cfg
    per_stage = sm.stage_multipliers(cfg)
    assert len(per_stage) == cfg.pp
    # synchronous barrier: each stage is paced by its slowest rank
    assert all(m in (1.0, 2.0) for m in per_stage)
    with pytest.raises(ValueError):
        StragglerModel(slow_fraction=1.5)


def test_perturb_rejects_bad_shapes():
    tr = _scenario(tp=2, pp=2, microbatches=4).trace()
    with pytest.raises(ValueError, match="pp"):
        tr.simulate(perturb=(1.0, 1.0, 1.0))
    with pytest.raises(ValueError, match="> 0"):
        tr.simulate(perturb=(1.0, -2.0))


# ---- elastic re-shard -----------------------------------------------------

def test_shrink_cfg_and_reshard_cost():
    sc = _scenario(dp=4, tp=2, pp=2, microbatches=4, fsdp=True)
    plan = elastic_reshard(lambda: sc.builder().graph, sc.env(), sc.cfg,
                           k=8, hw=sc._effective_hw(TPU_V5E),
                           mem=sc.trace().memory())
    assert plan.old_world == 16 and plan.new_world == 8
    assert plan.cfg.degree("dp") == 2 and plan.cfg.world == 8
    # FSDP shards grow when dp shrinks: bytes move, time is charged
    assert plan.reshard_bytes > 0 and plan.reshard_time > 0
    assert plan.dist_report is not None

    # replicated dp: shrink is free (survivors already hold full state)
    sc2 = _scenario(dp=4, tp=2, pp=2, microbatches=4)
    plan2 = elastic_reshard(lambda: sc2.builder().graph, sc2.env(), sc2.cfg,
                            k=8, hw=sc2._effective_hw(TPU_V5E),
                            mem=sc2.trace().memory())
    assert plan2.reshard_bytes == 0 and plan2.reshard_time == 0

    with pytest.raises(ValueError):
        shrink_cfg(sc.cfg, 16)               # nothing left
    with pytest.raises(ValueError):
        shrink_cfg(sc.cfg, 13)               # < one model replica survives
    with pytest.raises(ValueError):
        shrink_cfg(_scenario(tp=2, pp=2, microbatches=4).cfg, 1)  # no dp


# ---- DSE ranking ----------------------------------------------------------

def _points(spec, hw, *cfg_kw):
    pts = []
    for kw in cfg_kw:
        sc = _scenario(**kw)
        tr = sc.trace()
        pts.append(DSEPoint(cfg=sc.cfg, sim=tr.simulate(hw),
                            mem=tr.memory(), label=sc.cfg.describe()))
    score_resilience(pts, spec, hw)
    return pts


def test_effective_goodput_flips_step_time_winner():
    """tp x pp-heavy wins on raw step time; dp-heavy (peer-recoverable,
    no checkpoint/rewind overhead) wins once failures are priced in."""
    spec = ResilienceSpec(mtbf={"chip": 20e3}, ckpt=SLOW)
    hw = _scenario(dp=16)._effective_hw(TPU_V5E)
    pts = _points(spec, hw,
                  dict(tp=4, pp=4, microbatches=2),       # model-parallel
                  dict(dp=16))                            # replicated
    mp, dp = pts
    assert mp.resilience.recovery == "storage"
    assert dp.resilience.recovery == "peer"
    assert mp.sim.step_time < dp.sim.step_time            # raw winner: mp
    rank_points(pts, "step_time")
    assert pts[0].label == mp.label
    rank_points(pts, "effective_goodput")
    assert pts[0].label == dp.label                       # flipped
    assert dp.effective_step_time < mp.effective_step_time
    with pytest.raises(ValueError):
        rank_points(pts, "tokens")


def test_sweep_rank_by_effective_goodput():
    sc = (Scenario(SMOKE).train(batch=8, seq=128).cluster(POD)
          .resilience(mtbf={"chip": 20e3}, ckpt=SLOW))
    res = sc.sweep(8, max_pp=2, rank_by="effective_goodput")
    assert res and all(p.resilience is not None for p in res)
    effs = [p.effective_step_time for p in res]
    assert effs == sorted(effs)
    assert "goodput" in res[0].row()
    with pytest.raises(ValueError, match="rank_by"):
        sc.sweep(8, rank_by="bogus")
    with pytest.raises(ValueError, match="resilience"):
        Scenario(SMOKE).train(batch=8, seq=128).sweep(
            8, rank_by="effective_goodput")


def test_failure_free_sweep_is_bit_identical():
    """The resilience-free path must not move by a single bit."""
    base = Scenario(SMOKE).train(batch=8, seq=128).cluster(POD)
    plain = base.sweep(8, max_pp=2)
    scored = base.resilience(mtbf=50e3).sweep(8, max_pp=2)
    assert [(p.label, p.sim.step_time, p.mem.peak_bytes) for p in plain] == \
           [(p.label, p.sim.step_time, p.mem.peak_bytes) for p in scored]
    # and simulate() with no perturb is the untouched code path
    tr = base.parallel(dp=2, tp=2, pp=2, microbatches=4).trace()
    assert tr.simulate().step_time == tr.simulate(perturb=None).step_time


def test_serving_sweep_rank_by_effective_goodput():
    job = (Scenario(SMOKE).cluster(POD)
           .resilience(mtbf={"chip": 5e3}, ckpt="local_ssd")
           .prefill(batch=4, seq=256).generation(out_tokens=16))
    pts = job.sweep(8, max_pp=2, rank_by="effective_goodput")
    assert pts and all(p.resilience is not None for p in pts)
    effs = [p.effective_tokens_per_s for p in pts]
    assert effs == sorted(effs, reverse=True)
    assert all(math.isinf(p.resilience.interval) for p in pts)


# ---- compiled state_bytes parity ------------------------------------------

def test_compiled_state_bytes_matches_memory_report():
    from repro.core.assemble import total_layers
    from repro.core.compiled import CompiledBackend
    for kw in (dict(dp=2, tp=2, pp=2, microbatches=4, fsdp=True),
               dict(dp=4, pp=2, microbatches=2, zero1=True),
               dict(tp=2, pp=4, microbatches=4)):
        sc = _scenario(**kw)
        be = CompiledBackend(lambda: sc.builder().graph, sc.env(),
                             n_layers=total_layers(SMOKE))
        assert be.state_bytes(sc.cfg) == \
            state_bytes(sc.trace().memory())


# ---- Chakra stamping + STG4xx ---------------------------------------------

RSPEC = ResilienceSpec(mtbf={"chip": 3e3, "nvlink": 5e3}, ckpt="local_ssd",
                       recovery="storage")


def _stamped_dir(tmp_path):
    sc = (Scenario(SMOKE).train(batch=8, seq=128).cluster(POD)
          .resilience(RSPEC).parallel(dp=2, tp=2, pp=2, microbatches=4))
    tr = sc.trace()
    n = tr.export_chakra(str(tmp_path), resilience=True,
                         resilience_steps=20_000_000)
    assert n == 8
    return tr


def test_chakra_stamping_roundtrip(tmp_path):
    tr = _stamped_dir(tmp_path)
    rep, events = tr.resilience_events(steps=20_000_000)
    assert events and rep.recovery == "storage"
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["resilience"]["events"] == len(events)
    assert man["resilience"]["recovery"] == "storage"
    out = check_trace_dir(str(tmp_path))
    assert out.ok, out.render()
    body = json.load(open(tmp_path / "rank0.json"))
    marks = [nd for nd in body["nodes"]
             if nd.get("attrs", {}).get("phase") == "resilience"]
    assert len(marks) == 2 * len(events)
    kinds = [nd["attrs"]["kind"] for nd in marks]
    assert kinds == ["failure", "restore"] * len(events)
    # ckpt_step monotone, times monotone
    cks = [nd["attrs"]["ckpt_step"] for nd in marks]
    assert cks == sorted(cks)
    # exports WITHOUT resilience stay byte-identical: no markers, no
    # manifest key
    plain = (Scenario(SMOKE).train(batch=8, seq=128).cluster(POD)
             .parallel(dp=2, tp=2, pp=2, microbatches=4).trace())
    d2 = tmp_path / "plain"
    plain.export_chakra(str(d2))
    man2 = json.load(open(d2 / "manifest.json"))
    assert "resilience" not in man2
    body2 = json.load(open(d2 / "rank0.json"))
    assert not [nd for nd in body2["nodes"]
                if nd.get("attrs", {}).get("phase") == "resilience"]


def _mutate_rank0(tmp_path, fn):
    f = tmp_path / "rank0.json"
    body = json.load(open(f))
    fn(body)
    json.dump(body, open(f, "w"))


def _marks(body):
    return [nd for nd in body["nodes"]
            if nd.get("attrs", {}).get("phase") == "resilience"]


def test_stg401_epoch_order(tmp_path):
    _stamped_dir(tmp_path)

    def swap_epochs(body):
        ms = _marks(body)
        ms[0]["attrs"]["epoch"], ms[2]["attrs"]["epoch"] = \
            ms[2]["attrs"]["epoch"], ms[0]["attrs"]["epoch"]
    _mutate_rank0(tmp_path, swap_epochs)
    out = check_trace_dir(str(tmp_path))
    assert "STG401" in out.codes()


def test_stg402_unmatched_pair(tmp_path):
    _stamped_dir(tmp_path)
    _mutate_rank0(tmp_path,
                  lambda body: body["nodes"].remove(_marks(body)[-1]))
    out = check_trace_dir(str(tmp_path))
    assert "STG402" in out.codes()


def test_stg403_manifest_disagreement(tmp_path):
    _stamped_dir(tmp_path)

    def drop_pair(body):
        for nd in _marks(body)[-2:]:
            body["nodes"].remove(nd)
    _mutate_rank0(tmp_path, drop_pair)
    out = check_trace_dir(str(tmp_path))
    assert "STG403" in out.codes()


def test_stg404_ckpt_regression(tmp_path):
    _stamped_dir(tmp_path)

    def rewind(body):
        ms = _marks(body)
        for nd in ms[-2:]:
            nd["attrs"]["ckpt_step"] = 0
        ms[0]["attrs"]["ckpt_step"] = 5
        ms[1]["attrs"]["ckpt_step"] = 5
    _mutate_rank0(tmp_path, rewind)
    out = check_trace_dir(str(tmp_path))
    assert "STG404" in out.codes()


def test_check_trace_accepts_stamped_stage_body():
    sc = (Scenario(SMOKE).train(batch=8, seq=128).cluster(POD)
          .resilience(RSPEC).parallel(dp=2, pp=2, microbatches=4))
    body = sc.trace().chakra_stage(0, resilience=True,
                                   resilience_steps=20_000_000)
    assert check_trace(body, rank=None, name="stage0").ok
    marks = [nd for nd in body["nodes"]
             if nd.get("attrs", {}).get("phase") == "resilience"]
    assert marks and marks[0]["attrs"]["kind"] == "failure"
