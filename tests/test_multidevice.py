"""Multi-device semantics checks (run in a subprocess with 8 placeholder
devices so the main pytest process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P, AxisType

    from repro.core import ModelSpec, MoESpec
    from repro.models import RuntimeCfg, init_params
    from repro.models import layers as L
    from repro.models.common import AxisRules
    from repro.parallel.sharding import logical_rules, param_shardings

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

    spec = ModelSpec(name="m", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=128, vocab=256,
                     moe=MoESpec(8, 2, 0, 32))
    # capacity high enough that no token ever drops: both paths must then
    # agree exactly (drop SETS legitimately differ at finite capacity
    # because local capacity quantizes per shard)
    rt = RuntimeCfg(attention_impl="naive", moe_capacity=8.0)
    params = init_params(spec, rt, jax.random.PRNGKey(0))
    moe_p = params["slots"][0]["moe"]
    import jax.tree_util as jtu
    moe_p = jtu.tree_map(lambda p: type(p)(p.value[0], p.axes[1:]), moe_p,
                         is_leaf=lambda x: hasattr(x, "axes"))

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64), jnp.float32) \\
        .astype(jnp.bfloat16)

    # 1. local (no-mesh) reference
    ref = L.moe_ffn(moe_p, x, spec, rt, None)

    # 2. shard_map EP path under the mesh
    rules_d = logical_rules(sp=False, data_axes=("data",))
    rules = AxisRules(rules_d)
    rules.mesh = mesh
    with jax.set_mesh(mesh):
        out = jax.jit(lambda p, a: L.moe_ffn(p, a, spec, rt, rules))(moe_p, x)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    scale = float(jnp.abs(ref.astype(jnp.float32)).max())
    assert err < 0.05 * scale + 1e-2, f"moe shard_map mismatch: {err} vs {scale}"
    print("MOE_EP_OK", err)

    # 3. param shardings: divisibility fallback (kv_heads=4 doesn't divide
    # model=4? it does; vocab=256 divides; check MQA fallback)
    spec2 = ModelSpec(name="mqa", n_layers=1, d_model=64, n_heads=4,
                      n_kv_heads=1, d_ff=128, vocab=250)
    p2 = init_params(spec2, rt, jax.random.PRNGKey(0))
    sh = param_shardings(p2, rules_d, mesh)
    wk = sh["slots"][0]["attn"]["w_k"]
    assert len(wk.spec) < 2 or wk.spec[1] is None, wk.spec  # kv=1 unsharded
    emb = sh["embed"]
    assert all(e != "model" for e in emb.spec), emb.spec    # 250 % 4 != 0
    print("PSPEC_OK")
""")


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE_EP_OK" in r.stdout and "PSPEC_OK" in r.stdout, r.stdout
