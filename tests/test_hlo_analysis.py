"""Trip-count-aware HLO walker: exactness on scans + collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HloCost, analyze_hlo


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, x)
    t = analyze_hlo(c.as_text())
    want = 2 * 128**3 * 10
    assert abs(t["flops"] - want) / want < 0.01


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t = analyze_hlo(_compile(g, x, x).as_text())
    want = 2 * 128**3 * 20
    assert abs(t["flops"] - want) / want < 0.02


def test_unrolled_matches_scanned():
    """FLOPs must be (approximately) representation-independent."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x, w):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=8)
        return out

    def unrolled(x, w):
        for _ in range(8):
            x = x @ w
        return x
    t1 = analyze_hlo(_compile(scanned, w, w).as_text())["flops"]
    t2 = analyze_hlo(_compile(unrolled, w, w).as_text())["flops"]
    assert abs(t1 - t2) / t2 < 0.02


def test_bytes_bounded_by_touched_memory():
    """A big elementwise chain shouldn't count more HBM traffic than a
    small multiple of the tensors it touches."""
    def f(x):
        for _ in range(4):
            x = jnp.tanh(x) * 2 + 1
        return x
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    t = analyze_hlo(_compile(f, x).as_text())
    touched = 1024 * 1024 * 4
    assert t["bytes"] <= 16 * touched


def test_dus_charged_at_slice_granularity():
    """Scan output stacking must not charge the full stacked buffer per
    iteration."""
    def f(x):
        def body(c, _):
            c = c + 1.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = analyze_hlo(_compile(f, x).as_text())
    slice_bytes = 256 * 256 * 4
    # 64 iterations x O(1) slices each, NOT 64 x the full [64,256,256] buffer
    assert t["bytes"] < 64 * 8 * slice_bytes


def test_collectives_empty_on_single_device():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = analyze_hlo(_compile(lambda a: a @ a, x).as_text())
    assert t["collective_bytes"] == 0.0
