"""Per-kernel allclose sweeps vs. the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6_scan import wkv6_bhsd


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("b,h,sq,sk,d", [
    (1, 1, 128, 128, 64),
    (2, 3, 256, 256, 64),
    (1, 2, 64, 384, 128),       # cross-ish: kv longer than q
    (2, 2, 96, 160, 80),        # non-128-multiple dims (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(b, h, sq, sk, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, h, sq, d), dtype)
    k = _rand(ks[1], (b, h, sk, d), dtype)
    v = _rand(ks[2], (b, h, sk, d), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, interpret=True,
                               block_q=64, block_k=128)
    want = ref.ref_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap", [(32, None), (None, 20.0),
                                            (64, 30.0)])
def test_flash_window_softcap(window, softcap):
    b, h, s, d = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(ks[i], (b, h, s, d), jnp.float32) for i in range(3))
    out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               softcap=softcap, interpret=True)
    want = ref.ref_attention(q, k, v, causal=True, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_flash_q_offset_decode():
    """Single-token decode against a longer KV context."""
    b, h, sk, d = 2, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (b, h, 1, d), jnp.float32)
    k = _rand(ks[1], (b, h, sk, d), jnp.float32)
    v = _rand(ks[2], (b, h, sk, d), jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=True, q_offset=sk - 1,
                               interpret=True)
    want = ref.ref_attention(q, k, v, causal=True, q_offset=sk - 1)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,s,d,chunk", [
    (1, 1, 64, 32, 32),
    (2, 2, 128, 64, 32),
    (1, 3, 96, 48, 32),          # d needs padding to 128
])
def test_wkv6_vs_ref(b, h, s, d, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = _rand(ks[0], (b, h, s, d), jnp.float32)
    k = _rand(ks[1], (b, h, s, d), jnp.float32)
    v = _rand(ks[2], (b, h, s, d), jnp.float32)
    dec = jax.random.uniform(ks[3], (b, h, s, d), minval=-2.0, maxval=0.5)
    w = jnp.exp(-jnp.exp(dec))
    u = _rand(ks[4], (h, d), jnp.float32) * 0.5
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    out, st = wkv6_bhsd(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    want_o, want_s = ref.ref_wkv(r, k, v, w, u, s0)
    np.testing.assert_allclose(out, want_o, atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(st, want_s, atol=1e-3, rtol=1e-2)


def test_wkv6_state_carry():
    """Two half-length calls with carried state == one full call."""
    b, h, s, d = 1, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r, k, v = (_rand(ks[i], (b, h, s, d), jnp.float32) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, h, s, d),
                                            minval=-2.0, maxval=0.0)))
    u = _rand(ks[4], (h, d), jnp.float32) * 0.5
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    full, st_full = wkv6_bhsd(r, k, v, w, u, s0, chunk=32, interpret=True)
    h1, st1 = wkv6_bhsd(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                        w[:, :, :32], u, s0, chunk=32, interpret=True)
    h2, st2 = wkv6_bhsd(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                        w[:, :, 32:], u, st1, chunk=32, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([h1, h2], axis=2), full,
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st2, st_full, atol=1e-4, rtol=1e-3)


@given(st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([32, 64]), st.sampled_from([16, 32]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_wkv6_property(b, h, s, d, seed):
    """Hypothesis: kernel == sequential oracle across random small shapes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (_rand(ks[i], (b, h, s, d), jnp.float32) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, h, s, d),
                                            minval=-1.5, maxval=0.5)))
    u = _rand(ks[4], (h, d), jnp.float32) * 0.3
    s0 = _rand(ks[4], (b, h, d, d), jnp.float32) * 0.1
    out, st_ = wkv6_bhsd(r, k, v, w, u, s0, chunk=min(32, s), interpret=True)
    want_o, want_s = ref.ref_wkv(r, k, v, w, u, s0)
    np.testing.assert_allclose(out, want_o, atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(st_, want_s, atol=2e-3, rtol=2e-2)


def test_model_layout_wrappers():
    """ops.flash_attention / ops.wkv6 adapt model layouts correctly."""
    B, S, N, G, D = 2, 64, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(ks[0], (B, S, N, G, D), jnp.float32)
    k = _rand(ks[1], (B, S, N, D), jnp.float32)
    v = _rand(ks[2], (B, S, N, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    qh = q.transpose(0, 2, 3, 1, 4).reshape(B, N * G, S, D)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    want = ref.ref_attention(qh, kh, vh, causal=True) \
        .reshape(B, N, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_ssm_chunked_vs_ref():
    from repro.models.layers import _ssm_scan
    b, s, d_, p_ = 2, 128, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    dA = jnp.exp(-jax.random.uniform(ks[0], (b, s, d_, p_), minval=0.0,
                                     maxval=2.0))
    dBx = jax.random.normal(ks[1], (b, s, d_, p_))
    h0 = jnp.zeros((b, d_, p_))
    hs, hl = _ssm_scan(dA, dBx, h0, chunk=32)
    want_hs, want_hl = ref.ref_ssm(dA, dBx, h0)
    np.testing.assert_allclose(hs, want_hs, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(hl, want_hl, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("b,e,t", [
    (1, 1, 1),
    (4, 7, 33),             # all dims below one tile (padding path)
    (128, 128, 128),        # exactly one tile
    (130, 257, 140),        # multi-tile with ragged remainders
])
def test_cost_reduce_vs_ref(b, e, t):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    x = jax.random.normal(ks[0], (b, t), jnp.float32)
    w = jax.random.normal(ks[1], (e, t), jnp.float32)
    out = ops.cost_reduce(x, w, interpret=True)
    want = x @ w.T
    assert out.shape == (b, e)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_cost_reduce_auto_path_f64():
    """Off-TPU the auto path is the jnp contraction in the input dtype —
    float64 under x64, double-precision-close to the numpy product
    (1e-14 would fail by ~7 digits if the reduction ran in float32)."""
    from repro.core.batched import _ensure_x64
    _ensure_x64()
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((5, 37)))
    w = jnp.asarray(rng.standard_normal((9, 37)))
    assert x.dtype == jnp.float64
    out = ops.cost_reduce(x, w)
    assert out.dtype == jnp.float64
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x) @ np.asarray(w).T,
                               rtol=1e-14, atol=1e-14)


def test_cost_reduce_counts_semantics():
    """Integer selection rows act as exact gather-sums (the batched
    backend's byte-access reductions): 0/1/k weights stay exact."""
    x = jnp.arange(1, 13, dtype=jnp.float32).reshape(2, 6)
    w = jnp.asarray([[1, 0, 1, 0, 0, 0],
                     [0, 2, 0, 0, 0, 3]], jnp.float32)
    out = ops.cost_reduce(x, w, interpret=True)
    want = np.asarray([[1 + 3, 2 * 2 + 3 * 6],
                       [7 + 9, 2 * 8 + 3 * 12]], np.float32)
    np.testing.assert_array_equal(np.asarray(out), want)
