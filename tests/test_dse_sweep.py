"""DSE sweep driver semantics: skip accounting, concurrency determinism,
and the Chakra export fast path introduced with the compiled backend."""
import json

import pytest

from repro import ParallelCfg, Scenario
from repro.core import ModelSpec
from repro.core.chakra import export_stage, rank_coords
from repro.core.dse import SkippedConfig, SweepResult, sweep
from repro.core.matcher import InfeasibleConfigError, MatchError
from repro.core.symbolic import Env

TINY = ModelSpec(name="tiny", n_layers=4, d_model=256, n_heads=8,
                 n_kv_heads=4, d_ff=512, vocab=4096)


# ---- skip accounting (no silent drops) ------------------------------------

def test_sweep_records_skipped_configs():
    def build():
        raise MatchError("cannot synthesize PartialSum over dp")

    res = sweep(build, Env(B=8, S=64), 4, n_layers=4, backend="sympy")
    assert isinstance(res, SweepResult)
    assert len(res) == 0
    assert len(res.skipped) > 0
    for sk in res.skipped:
        assert isinstance(sk, SkippedConfig)
        assert "PartialSum" in sk.reason
        assert isinstance(sk.cfg, ParallelCfg)


def test_sweep_propagates_unexpected_errors():
    def build():
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        sweep(build, Env(B=8, S=64), 4, n_layers=4, backend="sympy")


def test_infeasible_error_is_value_error_subclass():
    # existing except ValueError call sites keep working
    assert issubclass(MatchError, InfeasibleConfigError)
    assert issubclass(InfeasibleConfigError, ValueError)


def test_sweep_rejects_unknown_backend():
    with pytest.raises(ValueError):
        sweep(lambda: None, Env(B=8, S=64), 4, n_layers=4, backend="numpy")


# ---- concurrency: deterministic ordering ----------------------------------

def _labels(res):
    return [(p.label, p.sim.step_time, p.mem.peak_bytes) for p in res]


def test_thread_workers_deterministic():
    sc = Scenario(TINY).train(batch=16, seq=64)
    serial = sc.sweep(16)
    threaded = sc.sweep(16, workers=2)
    assert _labels(serial) == _labels(threaded)


def test_process_workers_deterministic():
    sc = Scenario(TINY).train(batch=16, seq=64)
    serial = sc.sweep(16)
    procs = sc.sweep(16, workers=2, executor="process")
    assert _labels(serial) == _labels(procs)


def test_concurrent_serial_sweeps_are_isolated():
    """Serial sweeps share the process-wide engine; launched from
    multiple threads they must not corrupt each other's scratch
    workloads (scratch is keyed per thread)."""
    from concurrent.futures import ThreadPoolExecutor
    sc = Scenario(TINY).train(batch=16, seq=64)
    ref = _labels(sc.sweep(16))
    with ThreadPoolExecutor(max_workers=4) as pool:
        futs = [pool.submit(lambda: _labels(sc.sweep(16)))
                for _ in range(8)]
        for f in futs:
            assert f.result() == ref


# ---- rank_coords validation -----------------------------------------------

def test_rank_coords_roundtrip():
    cfg = ParallelCfg(axes={"dp": 2, "tp": 4}, dp_axis="dp", tp_axis="tp",
                      sp=True, pp=2)
    seen = set()
    for rank in range(cfg.world):
        c = rank_coords(rank, cfg)
        assert 0 <= c["dp"] < 2 and 0 <= c["tp"] < 4 and 0 <= c["pp"] < 2
        seen.add((c["dp"], c["tp"], c["pp"]))
    assert len(seen) == cfg.world


@pytest.mark.parametrize("rank", [-1, 16, 1000])
def test_rank_coords_out_of_range(rank):
    cfg = ParallelCfg(axes={"dp": 2, "tp": 4}, dp_axis="dp", tp_axis="tp",
                      sp=True, pp=2)
    with pytest.raises(ValueError, match="out of range"):
        rank_coords(rank, cfg)


def test_rank_coords_rejects_ranks_beyond_pipeline():
    # world = pp * prod(axes): the first rank past the last pipeline
    # stage's replicas is rejected (range check subsumes the pp bound)
    cfg = ParallelCfg(axes={"dp": 2}, dp_axis="dp", pp=2)
    assert rank_coords(3, cfg)["pp"] == 1      # last valid rank
    with pytest.raises(ValueError, match="out of range"):
        rank_coords(4, cfg)


def test_schedule_matches_costmodel():
    """_schedule inlines the roofline/ring cost model for speed; pin it
    to costmodel.node_time so the two cannot silently diverge."""
    from repro import TPU_V5E
    from repro.core.costmodel import node_time
    from repro.core.simulate import _schedule

    w = Scenario(TINY).train(batch=8, seq=64).parallel(
        dp=2, tp=2, sp=True).trace().workload
    nodes = w.stage_nodes(0)
    makespan, cbusy, mbusy = _schedule(nodes, TPU_V5E)
    # reference replay using the public cost model
    finish, free = {}, {"compute": 0.0, "comm": 0.0}
    busy = {"compute": 0.0, "comm": 0.0}
    for n in nodes:
        dur = node_time(n, TPU_V5E)
        stream = "comm" if n.comm is not None else "compute"
        ready = max((finish.get(d, 0.0) for d in n.deps), default=0.0)
        end = max(ready, free[stream]) + dur
        finish[n.uid] = end
        free[stream] = end
        busy[stream] += dur
    assert makespan == max(free.values())
    assert cbusy == busy["compute"] and mbusy == busy["comm"]


# ---- chakra export: pre-serialized stamping --------------------------------

def test_export_ranks_splices_preserialized_stage(tmp_path):
    tr = Scenario(TINY).train(batch=8, seq=64).parallel(
        dp=2, tp=2, sp=True, pp=2, microbatches=2).trace()
    n = tr.export_chakra(str(tmp_path), ranks=range(8))
    assert n == 8
    w = tr.workload
    for rank in (0, 5, 7):
        got = json.load(open(tmp_path / f"rank{rank}.json"))
        coords = rank_coords(rank, w.cfg)
        want = dict(export_stage(w, coords["pp"]))
        want["rank"] = rank
        want["coords"] = coords
        assert got == want
    # stamped traces for ranks of the same stage share the node body
    r0 = json.load(open(tmp_path / "rank0.json"))
    r1 = json.load(open(tmp_path / "rank1.json"))
    assert r0["nodes"] == r1["nodes"] and r0["coords"] != r1["coords"]


def test_export_ranks_rejects_bad_rank(tmp_path):
    tr = Scenario(TINY).train(batch=8, seq=64).parallel(dp=2).trace()
    with pytest.raises(ValueError, match="out of range"):
        tr.export_chakra(str(tmp_path), ranks=[99])
