"""DSE search machinery: enumerate_configs edges, Pareto-front
extraction, and branch-and-bound exactness.

The headline guarantee under test: ``search="bnb"`` returns EXACTLY the
front that exhaustive enumeration + ``pareto_front`` would, while fully
evaluating well under 25% of the (pinned) config space — the pruning
rule only discards configs whose closed-form lower-bound vector is
already strictly dominated by an evaluated point, so no front member
can ever be pruned.
"""
import pytest

from repro import Scenario
from repro.configs import get
from repro.core.dse import (DSEPoint, _pow2_divisors, enumerate_configs,
                            pareto_front)

# ---- enumerate_configs edges ------------------------------------------------


def test_pow2_divisors():
    assert _pow2_divisors(1) == [1]
    assert _pow2_divisors(16) == [1, 2, 4, 8, 16]
    assert _pow2_divisors(12) == [1, 2, 4]
    assert _pow2_divisors(24) == [1, 2, 4, 8]
    assert _pow2_divisors(6) == [1, 2]
    assert _pow2_divisors(7) == [1]


def test_enumerate_world_one():
    cfgs = list(enumerate_configs(1))
    assert len(cfgs) == 1
    c = cfgs[0]
    assert c.axes == {} and c.pp == 1 and not c.fsdp


def test_enumerate_non_pow2_world():
    """Non-power-of-two worlds factorize over pow2 divisors; the
    residual factor lands in dp (dp = world / (tp*cp*pp))."""
    cfgs = list(enumerate_configs(12, with_fsdp=False))
    assert cfgs
    for c in cfgs:
        tp = c.axes.get("tp", 1)
        cp = c.axes.get("cp", 1)
        dp = c.axes.get("dp", 1)
        assert dp * tp * cp * c.pp == 12
        assert tp in (1, 2, 4) and c.pp in (1, 2, 4)
    # dp always absorbs the odd factor 3, so dp is a multiple of 3
    assert all(c.axes.get("dp", 1) % 3 == 0 for c in cfgs)


def test_enumerate_caps_bind():
    base = list(enumerate_configs(16, with_fsdp=False))
    assert any(c.axes.get("tp", 1) > 2 for c in base)
    assert any(c.pp > 2 for c in base)
    capped = list(enumerate_configs(16, with_fsdp=False, max_tp=2, max_pp=2))
    assert capped
    assert all(c.axes.get("tp", 1) <= 2 for c in capped)
    assert all(c.pp <= 2 for c in capped)
    assert all(c.axes.get("cp", 1) <= 4
               for c in enumerate_configs(16, max_cp=4))


def test_enumerate_microbatch_iterable():
    """An iterable microbatches makes mb a swept dimension; pp=1 points
    sweep it too (the batched backend evaluates that axis in-batch)."""
    cfgs = list(enumerate_configs(4, with_fsdp=False,
                                  microbatches=(1, 2, 4)))
    flat = [c for c in cfgs if c.pp == 1]
    piped = [c for c in cfgs if c.pp > 1]
    assert sorted({c.microbatches for c in flat}) == [1, 2, 4]
    assert sorted({c.microbatches for c in piped}) == [1, 2, 4]
    # scalar form unchanged
    assert all(c.microbatches == 2
               for c in enumerate_configs(4, microbatches=2))


def test_enumerate_schedule_iterable_only_differentiates_pipelined():
    cfgs = list(enumerate_configs(8, with_fsdp=False,
                                  schedule=("1f1b", "gpipe")))
    flat = [c for c in cfgs if c.pp == 1]
    assert len({c.schedule for c in flat}) == 1
    piped = [c for c in cfgs if c.pp > 1]
    assert {c.schedule for c in piped} == {"1f1b", "gpipe"}


# ---- pareto_front -----------------------------------------------------------


class _P:
    """Bare objective carrier quacking like a DSEPoint."""

    def __init__(self, step, peak, eff=None):
        self.step_ms = step
        self.peak_gb = peak
        self.effective_step_ms = eff if eff is not None else step


def _brute_front(pts):
    objs = [(p.step_ms, p.peak_gb, p.effective_step_ms) for p in pts]

    def dominated(i):
        return any(o != objs[i] and all(a <= b for a, b in zip(o, objs[i]))
                   for o in objs)
    return [p for i, p in enumerate(pts) if not dominated(i)]


def test_pareto_front_brute_force():
    import random
    rng = random.Random(7)
    pts = [_P(rng.randint(1, 20), rng.randint(1, 20), rng.randint(1, 20))
           for _ in range(200)]
    got = pareto_front(pts)
    want = _brute_front(pts)
    assert [id(p) for p in got] == [id(p) for p in want]


def test_pareto_front_keeps_ties_and_order():
    a, b = _P(1.0, 5.0), _P(1.0, 5.0)        # exact tie: both kept
    c = _P(2.0, 4.0)                          # tradeoff: kept
    d = _P(2.0, 5.0)                          # dominated by a/b
    got = pareto_front([d, c, b, a])
    assert got == [c, b, a]                   # input order preserved


def test_pareto_front_trivial():
    assert pareto_front([]) == []
    p = _P(1.0, 1.0)
    assert pareto_front([p]) == [p]


# ---- branch-and-bound -------------------------------------------------------

SPACE = dict(microbatches=(1, 2, 4, 8), schedule=("1f1b", "gpipe"))


@pytest.fixture(scope="module")
def scenario():
    return Scenario(get("qwen3-14b").smoke).train(batch=32, seq=64)


def test_bnb_exact_front_with_pruning(scenario):
    """Pinned <= 2000-config space: bnb returns the exhaustive front
    exactly while fully evaluating < 25% of the feasible configs."""
    full = scenario.sweep(16, search="pareto", **SPACE)
    bnb = scenario.sweep(16, search="bnb", **SPACE)
    assert len(full) > 0
    assert sorted(p.cfg.describe() for p in full) == \
        sorted(p.cfg.describe() for p in bnb)
    for a, b in zip(sorted(full, key=lambda p: p.label),
                    sorted(bnb, key=lambda p: p.label)):
        assert a.sim.step_time == b.sim.step_time
        assert a.mem.peak_bytes == b.mem.peak_bytes
    assert bnb.total <= 2000
    assert bnb.visited < 0.25 * bnb.total, (bnb.visited, bnb.total)
    assert bnb.search == "bnb" and full.search == "pareto"
    assert "branch-and-bound" in bnb.summary()


def test_bnb_exact_front_all_schedules(scenario):
    """zb-h1 (no critical-path bound) and interleaved stay exact."""
    space = dict(microbatches=(2, 4, 8),
                 schedule=("1f1b", "gpipe", "interleaved", "zb-h1"))
    full = scenario.sweep(8, search="pareto", **space)
    bnb = scenario.sweep(8, search="bnb", **space)
    assert sorted(p.cfg.describe() for p in full) == \
        sorted(p.cfg.describe() for p in bnb)
    assert bnb.visited < bnb.total


def test_pareto_search_via_api(scenario):
    """search="pareto" returns the front of the full evaluation with
    accounting fields populated."""
    full = scenario.sweep(8, **SPACE)
    front = scenario.sweep(8, search="pareto", **SPACE)
    assert front.evaluated == len(full)
    labels = {p.label for p in full}
    assert all(p.label in labels for p in front)
    assert 0 < len(front) <= len(full)
    assert "Pareto-front" in front.summary()


def test_bnb_rejects_sympy(scenario):
    with pytest.raises(ValueError, match="bnb"):
        scenario.with_backend("sympy").sweep(8, search="bnb", **SPACE)


def test_unknown_search_rejected(scenario):
    with pytest.raises(ValueError, match="search"):
        scenario.sweep(8, search="hillclimb", **SPACE)


def test_bnb_respects_mem_limit_and_resilience(scenario):
    """OOM labelling and resilience scoring survive the bnb path."""
    from repro.ft import ResilienceSpec
    res = scenario.sweep(16, search="bnb", mem_limit_gb=16.0,
                         resilience=ResilienceSpec(mtbf=30e3), **SPACE)
    assert all(p.resilience is not None for p in res)
    for p in res:
        assert ("(OOM)" in p.label) == (p.peak_gb > 16.0)


def test_full_sweep_unchanged_shape(scenario):
    """Default search="full" still returns every feasible point ranked
    by step time (SweepResult list semantics untouched)."""
    res = scenario.sweep(8, **SPACE)
    assert isinstance(res[0], DSEPoint)
    steps = [p.sim.step_time for p in res]
    assert steps == sorted(steps)
    assert res.search == "full"
