"""Topology-aware network subsystem: hierarchical tiers, axis placement,
collective-algorithm models, the link_bw_axis deprecation shim, and the
pinned hidden-vs-exposed comm accounting of SimResult."""
import warnings

import pytest

from repro import (H100_HGX_POD, TPU_V5E, ClusterTopology, HardwareProfile,
                   ParallelCfg, Scenario, Tier)
from repro.core import ModelSpec
from repro.core.collectives import CollectiveModel, comm_model, \
    valid_algorithms
from repro.core.dse import enumerate_configs
from repro.core.instantiate import NodeRec, Workload
from repro.core.simulate import simulate
from repro.core.symbolic import Env
from repro.core.topology import (axis_span, flat, h100_hgx_pod,
                                 normalize_placement)

TINY = ModelSpec(name="tiny", n_layers=4, d_model=256, n_heads=8,
                 n_kv_heads=4, d_ff=512, vocab=4096)

# 2 nodes x 2 chips, zero latency, fast intra / slow inter — every
# number below is hand-computable
TOY_TOPO = ClusterTopology("toy", (Tier("nv", 2, 2e9, 0.0),
                                   Tier("ib", 2, 1e9, 0.0)))


def _cfg(axes, placement=(), pp=1):
    return ParallelCfg(axes=dict(axes),
                       dp_axis="dp" if "dp" in axes else None,
                       tp_axis="tp" if "tp" in axes else None,
                       sp="tp" in axes, pp=pp, placement=placement)


def _comm(coll, axis, group, size, wire):
    return {"coll": coll, "axis": axis, "group": group,
            "size": size, "wire": wire}


# ---- topology structure ----------------------------------------------------

def test_capacities_and_extent_tiers():
    topo = h100_hgx_pod(4)                    # 8-GPU NVLink boxes, IB rails
    assert topo.devices == 32
    assert topo.capacities() == (8, 32)
    assert topo.tier_for_extent(2).name == "nvlink"
    assert topo.tier_for_extent(8).name == "nvlink"
    assert topo.tier_for_extent(9).name == "ib"
    # spans beyond the described cluster clamp to the outermost tier
    assert topo.tier_for_extent(1024).name == "ib"


def test_inner_split():
    topo = h100_hgx_pod(4)
    assert topo.inner_split(1, 16) == (8, 2)   # 8 per node, 2 nodes
    assert topo.inner_split(1, 4) == (4, 1)    # fits one node
    assert topo.inner_split(8, 4) == (1, 4)    # stride jumps nodes: flat
    assert topo.inner_split(2, 8) == (4, 2)    # 4 per node at stride 2


def test_inner_split_unaligned_stride_falls_back_flat():
    """Stride 3 on 8-wide nodes: members sit at ranks 0,3,6,9,... — rank
    pairs straddle node boundaries at varying offsets, so no uniform
    two-level split exists and the group must be costed flat (otherwise
    cross-node hops would be charged at intra-node bandwidth)."""
    topo = h100_hgx_pod(4)
    assert topo.inner_split(3, 4) == (1, 4)
    assert topo.inner_split(5, 8) == (1, 8)
    # aligned strides keep the hierarchical split
    assert topo.inner_split(4, 8) == (2, 4)


def test_tier_validation():
    with pytest.raises(ValueError):
        Tier("bad", 0, 1e9, 0.0)
    with pytest.raises(ValueError):
        Tier("bad", 2, 0.0, 0.0)
    with pytest.raises(ValueError):
        ClusterTopology("empty", ())


# ---- placement -------------------------------------------------------------

def test_axis_span_default_and_custom_placement():
    cfg = _cfg({"dp": 4, "tp": 8}, pp=2)
    # default: mesh order, pp outermost
    assert axis_span(cfg, "dp") == (1, 4)
    assert axis_span(cfg, "tp") == (4, 8)
    assert axis_span(cfg, "pp") == (32, 2)
    cfg2 = _cfg({"dp": 4, "tp": 8}, placement=("tp", "dp", "pp"), pp=2)
    assert axis_span(cfg2, "tp") == (1, 8)
    assert axis_span(cfg2, "dp") == (8, 4)
    assert axis_span(cfg2, "pp") == (32, 2)


def test_normalize_placement_projects_and_appends():
    assert normalize_placement(("tp", "dp"), {"dp": 4}) == ("dp", "pp")
    assert normalize_placement(("tp", "dp"), {"dp": 4, "tp": 2}) == \
        ("tp", "dp", "pp")
    assert normalize_placement(("pp", "tp"), {"tp": 2, "cp": 2}) == \
        ("pp", "tp", "cp")
    with pytest.raises(ValueError):
        normalize_placement(("tp", "tp"), {"tp": 2})


def test_parallel_cfg_placement_validation():
    with pytest.raises(ValueError, match="not in mesh"):
        _cfg({"dp": 2}, placement=("ep", "dp"))
    with pytest.raises(ValueError, match="repeats"):
        _cfg({"dp": 2}, placement=("dp", "dp"))
    with pytest.raises(ValueError, match="every mesh axis"):
        _cfg({"dp": 2, "tp": 2}, placement=("dp",))
    # "pp" is appended outermost when omitted
    assert _cfg({"dp": 2}, placement=("dp",)).placement == ("dp", "pp")


def test_describe_shows_non_default_placement():
    cfg = _cfg({"dp": 2, "tp": 2}, placement=("tp", "dp", "pp"))
    assert "place=tp.dp.pp" in cfg.describe()
    # the default order is not echoed
    assert "place=" not in _cfg({"dp": 2, "tp": 2},
                                placement=("dp", "tp", "pp")).describe()


# ---- collective algorithm models ------------------------------------------

def test_ring_intra_vs_cross_node():
    """Same group size: intra-node ring <= cross-node ring."""
    model = CollectiveModel(TOY_TOPO, cfg=_cfg({"tp": 2, "dp": 2}))
    size = 1e9
    intra = model.time_of(_comm("AllGather", "tp", 2, size, size / 2))
    cross = model.time_of(_comm("AllGather", "dp", 2, size, size / 2))
    assert intra == size / 2 / 2e9            # nv tier
    assert cross == size / 2 / 1e9            # ib tier
    assert intra < cross


def test_hierarchical_allreduce_beats_flat_ring_across_nodes():
    cfg = _cfg({"dp": 4})
    model = CollectiveModel(TOY_TOPO, cfg=cfg)
    size = 1e9
    wire = size * 2 * 3 / 4
    auto = model.time_of(_comm("AllReduce", "dp", 4, size, wire))
    ring = model.with_algorithm("AllReduce", "ring").time_of(
        _comm("AllReduce", "dp", 4, size, wire))
    # hand computation: hier = 2·(size/2)/2e9 + 2·(size/2/2)/1e9 = 1.0 s
    #                   ring = wire / 1e9 = 1.5 s (all traffic on IB)
    assert auto == pytest.approx(1.0)
    assert ring == pytest.approx(1.5)
    assert auto < ring


def test_allreduce_degrades_to_ring_inside_one_node():
    cfg = _cfg({"tp": 2})
    model = CollectiveModel(TOY_TOPO, cfg=cfg)
    assert model.describe("AllReduce", "tp", 2)["algorithm"] == "ring"
    t = model.time_of(_comm("AllReduce", "tp", 2, 1e9, 1e9))
    assert t == 1e9 / 2e9                     # wire/bw on the nv tier


def test_alltoall_pairwise_splits_tiers():
    """AllToAll's own cost: size/g to each peer — intra peers on the
    fast tier, remote peers on the bottleneck tier."""
    cfg = _cfg({"dp": 4})
    model = CollectiveModel(TOY_TOPO, cfg=cfg)
    size = 4e9                                 # size/g = 1e9 per peer
    wire = size * 3 / 4
    t = model.time_of(_comm("AllToAll", "dp", 4, size, wire))
    # 1 intra peer at 2 GB/s + 2 remote peers at 1 GB/s
    assert t == pytest.approx(1e9 / 2e9 + 2e9 / 1e9)
    # flat ring at the bottleneck would be wire/bw = 3 s
    assert t < wire / 1e9


def test_sendrecv_charged_one_hop_of_crossed_tier():
    lat_topo = ClusterTopology("lat", (Tier("nv", 2, 1e12, 1e-6),
                                       Tier("ib", 2, 1e12, 1e-3)))
    inner = CollectiveModel(
        lat_topo, cfg=_cfg({"dp": 2}, placement=("pp", "dp"), pp=2))
    outer = CollectiveModel(
        lat_topo, cfg=_cfg({"dp": 2}, placement=("dp", "pp"), pp=2))
    sr = _comm("SendRecv", "pp", 2, 8.0, 8.0)
    # ONE hop of the crossed tier — the latency IS the tier's, not a
    # ring-step count
    assert inner.time_of(sr) == pytest.approx(8.0 / 1e12 + 1e-6)
    assert outer.time_of(sr) == pytest.approx(8.0 / 1e12 + 1e-3)
    assert inner.time_of(sr) < outer.time_of(sr)


def test_sendrecv_straddling_axis_charged_worst_hop():
    """pp straddling a node boundary mid-axis: with tp=4 inner and pp=4
    on 2x8 nodes the stage1->stage2 hop (rank 4..7 -> 8..11) crosses IB
    even though stage0->stage1 stays on NVLink — the per-stage
    representative SendRecv record must be charged the slowest hop."""
    topo = h100_hgx_pod(2)                     # caps (8, 16)
    cfg = ParallelCfg(axes={"tp": 4}, tp_axis="tp", sp=True, pp=4,
                      placement=("tp", "pp"))
    model = CollectiveModel(topo, cfg=cfg)
    sr = _comm("SendRecv", "pp", 2, 1e9, 1e9)
    assert model.describe("SendRecv", "pp", 2)["tier"] == "ib"
    assert model.time_of(sr) == pytest.approx(1e9 / 50e9 + 5e-6)
    # a pp axis that fits entirely inside one node keeps the fast tier
    cfg2 = ParallelCfg(axes={"tp": 4}, tp_axis="tp", sp=True, pp=2,
                       placement=("tp", "pp"))
    model2 = CollectiveModel(topo, cfg=cfg2)
    assert model2.describe("SendRecv", "pp", 2)["tier"] == "nvlink"


def test_halving_doubling_and_tree_latency_scaling():
    lat_topo = ClusterTopology("lat", (Tier("nv", 16, 1e12, 1e-6),))
    cfg = _cfg({"dp": 16})
    ar = _comm("AllReduce", "dp", 16, 1e3, 2e3 * 15 / 16)
    ring = CollectiveModel(lat_topo, cfg=cfg).with_algorithm(
        "AllReduce", "ring").time_of(ar)
    hd = CollectiveModel(lat_topo, cfg=cfg).with_algorithm(
        "AllReduce", "halving_doubling").time_of(ar)
    tree = CollectiveModel(lat_topo, cfg=cfg).with_algorithm(
        "AllReduce", "tree").time_of(ar)
    # tiny message: latency dominates — 2·(g-1)=30 ring steps vs
    # 2·log2(16)=8 for both log-round algorithms
    assert hd < ring and tree < ring


def test_invalid_algorithm_rejected():
    with pytest.raises(ValueError, match="not valid"):
        CollectiveModel(TOY_TOPO).with_algorithm("AllReduce", "p2p")
    with pytest.raises(ValueError, match="not valid"):
        comm_model(TPU_V5E, algorithms={"SendRecv": "ring"})
    assert "hier_ring" in valid_algorithms("AllReduce")
    assert valid_algorithms("SendRecv") == ("p2p",)


def test_algorithm_override_without_topology_is_loud():
    """Overrides on a flat profile would silently cost as the legacy
    ring — the model refuses instead of no-opping."""
    with pytest.raises(ValueError, match="require a ClusterTopology"):
        comm_model(TPU_V5E, algorithms={"AllReduce": "tree"})
    sc = Scenario(TINY).train(batch=8, seq=64).parallel(dp=2) \
        .with_algorithm("AllReduce", "tree")
    with pytest.raises(ValueError, match="require a ClusterTopology"):
        sc.trace().simulate(TPU_V5E)


def test_describe_reports_effective_algorithm():
    """A forced hier_ring that degenerates (no two levels) is stamped —
    and costed — as the ring that actually runs."""
    model = CollectiveModel(TOY_TOPO, cfg=_cfg({"tp": 2})) \
        .with_algorithm("AllReduce", "hier_ring")
    assert model.describe("AllReduce", "tp", 2)["algorithm"] == "ring"
    # cost agrees with the stamped algorithm, not the requested one
    assert model.time_of(_comm("AllReduce", "tp", 2, 1e9, 1e9)) == 1e9 / 2e9


def test_group_of_one_is_free():
    model = CollectiveModel(TOY_TOPO, cfg=_cfg({"dp": 4}))
    assert model.time_of(_comm("AllReduce", "dp", 1, 1e9, 0.0)) == 0.0


# ---- link_bw_axis deprecation + parity shim --------------------------------

def test_link_bw_axis_warns():
    with pytest.warns(DeprecationWarning, match="link_bw_axis"):
        HardwareProfile(name="old", peak_flops=1e12, hbm_bw=1e12,
                        link_bw=50e9, link_bw_axis={"dp": 25e9})


def test_topology_profile_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        HardwareProfile(name="new", peak_flops=1e12, hbm_bw=1e12,
                        link_bw=50e9, topology=h100_hgx_pod(2))


def test_replace_of_bundled_profile_does_not_warn():
    """dataclasses.replace what-ifs on TPU_V5E/H100_HGX carry the
    bundled link_bw_axis the user never set — they must stay silent."""
    import dataclasses

    from repro import H100_HGX
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        dataclasses.replace(TPU_V5E, mem_capacity=32 * 2**30)
        dataclasses.replace(H100_HGX, peak_flops=1e15)
    # but changing the deprecated field itself is a new use: warn
    with pytest.warns(DeprecationWarning, match="link_bw_axis"):
        dataclasses.replace(TPU_V5E, link_bw_axis={"pod": 10e9})


def test_flat_topology_parity_shim():
    """A single-tier topology must reproduce the legacy flat model
    bit-for-bit (==, not approx): the deprecation path and its
    replacement agree wherever both can express the cluster."""
    legacy = HardwareProfile(name="legacy-flat", peak_flops=197e12,
                             hbm_bw=819e9, link_bw=50e9, link_latency=2e-6)
    shim = legacy.with_topology(flat(64, 50e9, 2e-6))
    assert shim.link_bw_axis == {}
    tr = (Scenario(TINY).train(batch=8, seq=64)
          .parallel(dp=2, tp=2, sp=True, pp=2, microbatches=2).trace())
    w = tr.workload
    a = simulate(w, legacy)
    b = simulate(w, shim)
    assert a.step_time == b.step_time
    assert a.comm_time == b.comm_time
    assert a.exposed_comm == b.exposed_comm
    assert a.overlap_ratio == b.overlap_ratio


# ---- SimResult hidden-vs-exposed accounting (pinned by hand) ---------------

def _toy_workload(nodes):
    return Workload(cfg=ParallelCfg(), env=Env(B=1, S=1), nodes=nodes,
                    stage_of={})


TOY_HW = HardwareProfile(name="toy-hw", peak_flops=1e9, hbm_bw=1e30,
                         link_bw=1e9, link_latency=0.0,
                         efficiency={"GeMM": 1.0})


def test_exposed_comm_accounting_hand_computed():
    """One 3 s compute op; a 2 s collective with no deps hides under it;
    a 1 s collective depending on the compute is fully exposed."""
    nodes = [
        NodeRec(1, "mm", "Einsum", "GeMM", "fwd", 0, flops=3e9),
        NodeRec(2, "ag", "Comm", "Comm", "fwd", 0,
                comm=_comm("AllGather", "dp", 2, 4e9, 2e9)),
        NodeRec(3, "ar", "Comm", "Comm", "fwd", 0,
                comm=_comm("AllReduce", "dp", 2, 0.5e9, 1e9), deps=(1,)),
    ]
    sim = simulate(_toy_workload(nodes), TOY_HW)
    # comm stream: ag [0,2] hidden; ar ready at 3, runs [3,4] exposed
    assert sim.step_time == pytest.approx(4.0)
    assert sim.compute_time == pytest.approx(3.0)
    assert sim.comm_time == pytest.approx(3.0)
    assert sim.exposed_comm == pytest.approx(1.0)
    assert sim.overlap_ratio == pytest.approx(2.0 / 3.0)


def test_fully_hidden_comm_has_overlap_one():
    nodes = [
        NodeRec(1, "mm", "Einsum", "GeMM", "fwd", 0, flops=5e9),
        NodeRec(2, "ag", "Comm", "Comm", "fwd", 0,
                comm=_comm("AllGather", "dp", 2, 4e9, 2e9)),
    ]
    sim = simulate(_toy_workload(nodes), TOY_HW)
    assert sim.exposed_comm == 0.0
    assert sim.overlap_ratio == 1.0


def test_exposed_comm_two_node_topology():
    """Same workload, hierarchical fabric: the cross-node collective
    slows down by the IB/NV ratio and the exposure grows accordingly."""
    hw = HardwareProfile(name="toy-topo", peak_flops=1e9, hbm_bw=1e30,
                         link_bw=2e9, efficiency={"GeMM": 1.0},
                         topology=TOY_TOPO)
    mk = lambda axes, placement: Workload(
        cfg=_cfg(axes, placement), env=Env(B=1, S=1), stage_of={},
        nodes=[
            NodeRec(1, "mm", "Einsum", "GeMM", "fwd", 0, flops=1e9),
            NodeRec(2, "ar", "Comm", "Comm", "fwd", 0,
                    comm=_comm("AllReduce", "dp", 2, 2e9, 4e9), deps=(1,)),
        ])
    intra = simulate(mk({"dp": 2, "tp": 2}, ("dp", "tp", "pp")), hw)
    cross = simulate(mk({"dp": 2, "tp": 2}, ("tp", "dp", "pp")), hw)
    # dp innermost: ring on NV at 2 GB/s -> 2 s; dp across nodes: IB at
    # 1 GB/s -> 4 s; both start after 1 s of compute, fully exposed
    assert intra.exposed_comm == pytest.approx(2.0)
    assert cross.exposed_comm == pytest.approx(4.0)
    assert intra.step_time < cross.step_time


# ---- end-to-end: Scenario API, sweeps, chakra ------------------------------

def test_scenario_placement_changes_time_not_bytes():
    sc = (Scenario(TINY).train(batch=32, seq=64)
          .parallel(dp=4, tp=8, sp=True).cluster(h100_hgx_pod(4)))
    tp_in = sc.placement("tp", "dp")
    dp_in = sc.placement("dp", "tp")
    s1 = tp_in.trace().simulate(H100_HGX_POD)
    s2 = dp_in.trace().simulate(H100_HGX_POD)
    assert s1.step_time < s2.step_time        # TP belongs on NVLink
    # bytes are placement-invariant (Table VII volumes unchanged)
    assert tp_in.trace().comm_volume() == dp_in.trace().comm_volume()
    assert tp_in.trace().op_counts() == dp_in.trace().op_counts()


def test_scenario_with_algorithm_override():
    sc = (Scenario(TINY).train(batch=32, seq=64)
          .parallel(dp=16).cluster(h100_hgx_pod(4)))
    auto = sc.trace().simulate(H100_HGX_POD)
    ring = sc.with_algorithm("AllReduce", "ring").trace() \
             .simulate(H100_HGX_POD)
    assert auto.step_time < ring.step_time    # hier beats flat over IB
    # per-call override matches the scenario-level one
    assert sc.trace().simulate(
        H100_HGX_POD, algorithms={"AllReduce": "ring"}).step_time \
        == ring.step_time


def test_enumerate_configs_placements_dimension():
    base = list(enumerate_configs(8, with_fsdp=False))
    swept = list(enumerate_configs(
        8, with_fsdp=False,
        placements=[("tp", "dp", "pp"), ("dp", "tp", "pp")]))
    assert len(swept) > len(base)
    # single-axis factorizations deduplicate to one placement
    labels = [c.describe() for c in swept]
    assert len(set(labels)) == len(labels)
    for c in swept:
        assert c.placement            # every swept cfg carries an order
        assert set(c.placement) == set(c.axes) | {"pp"}


def test_sweep_with_placements_ranks_tp_innermost_first():
    sc = (Scenario(TINY).train(batch=32, seq=64)
          .cluster(h100_hgx_pod(4)))
    res = sc.sweep(32, H100_HGX_POD, max_pp=1, with_fsdp=False,
                   placements=[("tp", "dp", "pp"), ("dp", "tp", "pp")])
    assert len(res) > 0
    by_label = {p.label: p for p in res}
    a = by_label.get("DP=4,TP=8,SP,place=tp.dp.pp")
    b = by_label.get("DP=4,TP=8,SP")          # dp.tp.pp == default order
    assert a is not None and b is not None
    assert a.sim.step_time < b.sim.step_time
    assert a.mem.peak_bytes == b.mem.peak_bytes   # memory is placement-blind


def test_chakra_stamps_topology_attrs(tmp_path):
    sc = (Scenario(TINY).train(batch=8, seq=64)
          .parallel(dp=2, tp=2, sp=True).placement("tp", "dp")
          .cluster(h100_hgx_pod(2)))
    trace = sc.trace().chakra_stage(0)
    comm_nodes = [n for n in trace["nodes"]
                  if n["type"].startswith("COMM_COLL")]
    assert comm_nodes
    for n in comm_nodes:
        assert n["attrs"]["tier"] in ("nvlink", "ib")
        assert n["attrs"]["algorithm"] in ("ring", "hier_ring", "pairwise")
        assert n["attrs"]["pg_stride"] >= 1
    # without a topology the export stays attribute-free (historical shape)
    plain = (Scenario(TINY).train(batch=8, seq=64)
             .parallel(dp=2, tp=2, sp=True).trace().chakra_stage(0))
    for n in plain["nodes"]:
        assert "tier" not in n["attrs"]


def test_rank_coords_follows_placement():
    from repro.core.chakra import rank_coords
    cfg = _cfg({"dp": 2, "tp": 4}, placement=("tp", "dp", "pp"), pp=2)
    seen = set()
    for rank in range(cfg.world):
        c = rank_coords(rank, cfg)
        seen.add((c["dp"], c["tp"], c["pp"]))
    assert len(seen) == cfg.world
    # tp innermost: consecutive ranks walk the tp coordinate first
    assert rank_coords(1, cfg) == {"tp": 1, "dp": 0, "pp": 0}
    assert rank_coords(4, cfg) == {"tp": 0, "dp": 1, "pp": 0}
    assert rank_coords(8, cfg) == {"tp": 0, "dp": 0, "pp": 1}


def test_rank_coords_placement_guards_mutated_cfg():
    """The defensive residual check survives the placement branch: a cfg
    whose mesh was shrunk after construction raises instead of silently
    mis-addressing ranks."""
    from repro.core.chakra import rank_coords
    cfg = _cfg({"dp": 2, "tp": 4}, placement=("tp", "dp", "pp"), pp=2)
    cfg.axes["cp"] = 2           # mutate post-construction: world is now
    with pytest.raises(ValueError, match="does not decompose"):
        rank_coords(17, cfg)     # 32 but the placement only covers 16
