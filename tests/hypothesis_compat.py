"""Degrade gracefully when ``hypothesis`` is absent.

Property-based tests skip with a clear reason while every plain test in
the same module still collects and runs (a bare module-level import
would otherwise fail collection for the whole file on containers that
don't ship hypothesis)."""
import functools

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Stands in for ``strategies`` — any attribute/call returns
        itself so module-level strategy construction still evaluates."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            @functools.wraps(fn)
            def stub():
                pass  # pragma: no cover — skipped before call
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
