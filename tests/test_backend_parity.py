"""Compiled-vs-sympy backend parity for every bundled model config.

The compiled backend (repro.core.compiled) must reproduce the reference
sympy evaluation path bit-identically — same per-GPU op counts, comm
volumes, FLOP totals, simulated step time, and peak memory — across
train and serve modes for each architecture family in
``src/repro/configs/``.  The numeric kernels mirror the reference
float-arithmetic order, so equality here is exact (``==``), not
approximate.
"""
import dataclasses

import pytest

from repro import Scenario, TPU_V5E
from repro.configs import ARCHS, get
from repro.core.schedules import SCHEDULES

MODES = ("train", "serve")

try:                                    # the bundled GPT3 paper config
    from benchmarks.paper_models import GPT3_5B
except ImportError:                     # pytest launched outside repo root
    from repro.core import ModelSpec
    GPT3_5B = ModelSpec(name="gpt3-5b", n_layers=24, d_model=4096,
                        n_heads=32, n_kv_heads=32, d_ff=16384, vocab=51200,
                        gated_ffn=False)

# same family (half-width layers, fewer of them) — symbolic graph size is
# what CI pays for, and that only depends on the layer count; the dims
# stay GEMM-dominated like the paper config so the zero-bubble split
# keeps its real backward weight-grad share
GPT3_SMOKE = dataclasses.replace(GPT3_5B, name="gpt3-5b-smoke", n_layers=8,
                                 d_model=2048, n_heads=16, n_kv_heads=16,
                                 d_ff=8192, vocab=4096)


def _vs(sched):
    return 2 if sched == "interleaved" else 1


def _scenario(spec, mode):
    sc = Scenario(spec)
    if mode == "train":
        sc = sc.train(batch=8, seq=64)
    else:
        sc = sc.serve(batch=4, kv_len=128)
    return sc.parallel(dp=2, tp=2, sp=True, pp=2, microbatches=2,
                       ep=spec.moe is not None)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("name", ARCHS)
def test_backend_parity(name, mode):
    spec = get(name).smoke
    sc = _scenario(spec, mode)
    ref = sc.with_backend("sympy").trace()
    cmp_ = sc.trace()

    # workload summaries (paper Tables VI/VII)
    for stage in range(ref.workload.stages):
        assert ref.op_counts(stage) == cmp_.op_counts(stage)
        assert ref.comm_counts(stage) == cmp_.comm_counts(stage)
        assert ref.comm_volume(stage) == cmp_.comm_volume(stage)
        assert ref.total_flops(stage) == cmp_.total_flops(stage)

    # analytic step time and peak memory, plain and with recompute
    for recompute in (False, True):
        s_ref = ref.simulate(TPU_V5E, recompute=recompute)
        s_cmp = cmp_.simulate(TPU_V5E, recompute=recompute)
        assert s_ref.step_time == s_cmp.step_time
        assert s_ref.exposed_comm == s_cmp.exposed_comm
        m_ref = ref.memory(recompute=recompute)
        m_cmp = cmp_.memory(recompute=recompute)
        for f in ("weights", "grads", "opt_states", "master_params",
                  "peak_activation", "inflight_factor", "recompute_extra"):
            assert getattr(m_ref, f) == getattr(m_cmp, f), f


def test_parity_per_node_tiny():
    """Node-level parity (names, costs, comm records, dep counts)."""
    spec = get("qwen3-14b").smoke
    sc = _scenario(spec, "train")
    wr = sc.with_backend("sympy").trace().workload
    wc = sc.trace().workload
    assert len(wr.nodes) == len(wc.nodes)
    for a, b in zip(wr.nodes, wc.nodes):
        assert (a.name, a.kind, a.category, a.phase, a.stage, a.vstage,
                a.wgrad, a.repeat) == \
               (b.name, b.kind, b.category, b.phase, b.stage, b.vstage,
                b.wgrad, b.repeat)
        assert a.flops == b.flops, a.name
        assert a.bytes_accessed == b.bytes_accessed, a.name
        assert a.out_bytes == b.out_bytes, a.name
        assert a.comm == b.comm, a.name
        assert len(a.deps) == len(b.deps), a.name
        assert a.tags == b.tags, a.name


def test_sweep_backend_parity():
    """Whole-sweep equality: same ranking, times, memory, skip lists."""
    spec = get("minitron-8b").smoke
    sc = Scenario(spec).train(batch=16, seq=64)
    ref = sc.with_backend("sympy").sweep(16)
    cmp_ = sc.sweep(16)
    assert len(ref) == len(cmp_) and len(ref) > 0
    assert len(ref.skipped) == len(cmp_.skipped)
    for a, b in zip(ref, cmp_):
        assert a.label == b.label
        assert a.sim.step_time == b.sim.step_time
        assert a.mem.peak_bytes == b.mem.peak_bytes


def test_fresh_workloads_are_isolated():
    """Mutating one compiled trace's node tags must not leak into other
    traces sharing the engine (same isolation as the sympy backend)."""
    spec = get("qwen3-14b").smoke
    sc = _scenario(spec, "train")
    w1 = sc.trace().workload
    w1.nodes[10].tags["poison"] = True
    w1.stage_of[w1.nodes[0].uid] = 99
    w2 = sc.trace().workload
    assert "poison" not in w2.nodes[10].tags
    assert w2.stage_of[w2.nodes[0].uid] != 99


def _gpt3_scenario(sched):
    return (Scenario(GPT3_SMOKE).train(batch=8, seq=512)
            .parallel(dp=2, pp=4, microbatches=8)
            .schedule(sched, vstages=_vs(sched)))


@pytest.mark.parametrize("sched", SCHEDULES)
def test_backend_parity_all_schedules(sched):
    """Compiled vs sympy must stay exactly equal under every pipeline
    schedule — the schedule replay is shared numeric post-processing, so
    equality is ==, not approx."""
    sc = _gpt3_scenario(sched)
    ref = sc.with_backend("sympy").trace()
    cmp_ = sc.trace()
    s_ref = ref.simulate(TPU_V5E)
    s_cmp = cmp_.simulate(TPU_V5E)
    assert s_ref.step_time == s_cmp.step_time
    assert s_ref.bubble_fraction == s_cmp.bubble_fraction
    assert s_ref.compute_time == s_cmp.compute_time
    assert s_ref.comm_time == s_cmp.comm_time
    assert s_ref.exposed_comm == s_cmp.exposed_comm
    for a, b in zip(s_ref.stages, s_cmp.stages):
        assert (a.t_fwd, a.t_bwd, a.t_opt) == (b.t_fwd, b.t_bwd, b.t_opt)
    for stage in range(ref.workload.stages):
        m_ref = ref.memory(stage=stage)
        m_cmp = cmp_.memory(stage=stage)
        assert m_ref.inflight_factor == m_cmp.inflight_factor
        assert m_ref.peak_bytes == m_cmp.peak_bytes


def test_bubble_fraction_ordering_gpt3():
    """On the bundled GPT3 config (pp=4, M=8): the literature ordering
    gpipe >= 1f1b >= interleaved >= zb-h1 must fall out of the replay,
    and 1F1B must stay within 5% of the closed form it replaced."""
    sims = {s: _gpt3_scenario(s).trace().simulate(TPU_V5E)
            for s in SCHEDULES}
    b = {k: v.bubble_fraction for k, v in sims.items()}
    assert b["gpipe"] >= b["1f1b"] - 1e-12, b
    assert b["1f1b"] >= b["interleaved"] - 1e-12, b
    assert b["interleaved"] >= b["zb-h1"] - 1e-12, b
    assert b["zb-h1"] > 0.0

    # previous closed form: (M + P - 1) * max_stage(t_mb) + t_opt over the
    # combined fwd+bwd microbatch span
    from repro.core.simulate import _schedule
    w = _gpt3_scenario("1f1b").trace().workload
    mb, pp = 8, 4
    spans, opts = [], []
    for s in range(w.stages):
        nodes = w.stage_nodes(s)
        spans.append(_schedule([n for n in nodes
                                if n.phase in ("fwd", "bwd")], TPU_V5E)[0])
        opts.append(_schedule([n for n in nodes if n.phase == "opt"],
                              TPU_V5E)[0])
    closed = (mb + pp - 1) * max(spans) + max(opts)
    assert abs(sims["1f1b"].step_time - closed) / closed < 0.05


def test_compiled_structure_classes_are_reused():
    """Second identical sweep must be pure replay: zero new compiles."""
    from repro import compiled_cache_stats
    spec = get("gemma2-27b").smoke
    sc = Scenario(spec).train(batch=8, seq=64)
    sc.sweep(8)
    before = compiled_cache_stats()
    sc.sweep(8)
    after = compiled_cache_stats()
    assert after["compiles"] == before["compiles"]
    assert after["hits"] > before["hits"]


# ---- topology-aware comm model: parity must survive placement/tiering ------

PLACEMENTS = (None, ("tp", "dp", "pp"), ("dp", "tp", "pp"),
              ("pp", "tp", "dp"), ("dp", "pp", "tp"))


@pytest.mark.parametrize("place", PLACEMENTS)
def test_backend_parity_topology_placements(place):
    """Hierarchical topologies + every axis placement: the collective
    model is shared simulate-side post-processing over bit-identical
    NodeRecs, so compiled vs sympy equality stays exact (==)."""
    from repro import H100_HGX_POD
    spec = get("qwen3-14b").smoke
    sc = _scenario(spec, "train")
    if place:
        sc = sc.placement(*place)
    ref = sc.with_backend("sympy").trace()
    cmp_ = sc.trace()
    s_ref = ref.simulate(H100_HGX_POD)
    s_cmp = cmp_.simulate(H100_HGX_POD)
    assert s_ref.step_time == s_cmp.step_time
    assert s_ref.compute_time == s_cmp.compute_time
    assert s_ref.comm_time == s_cmp.comm_time
    assert s_ref.exposed_comm == s_cmp.exposed_comm
    assert s_ref.bubble_fraction == s_cmp.bubble_fraction


@pytest.mark.parametrize("algo", ["ring", "hier_ring", "halving_doubling",
                                  "tree"])
def test_backend_parity_algorithm_overrides(algo):
    from repro import H100_HGX_POD
    spec = get("minitron-8b").smoke
    sc = _scenario(spec, "train").placement("tp", "dp", "pp") \
        .with_algorithm("AllReduce", algo)
    s_ref = sc.with_backend("sympy").trace().simulate(H100_HGX_POD)
    s_cmp = sc.trace().simulate(H100_HGX_POD)
    assert s_ref.step_time == s_cmp.step_time
    assert s_ref.exposed_comm == s_cmp.exposed_comm


def test_comm_volumes_invariant_under_topology_and_placement():
    """Topology/placement change collective *time*, never bytes: the
    Table VII volumes and per-node comm records are identical with and
    without a cluster (table7_commvol.py output is pinned by this)."""
    from repro.core.topology import h100_hgx_pod
    spec = get("qwen3-14b").smoke
    base = _scenario(spec, "train")
    placed = base.cluster(h100_hgx_pod(4)).placement("tp", "dp", "pp")
    wb, wp = base.trace().workload, placed.trace().workload
    for stage in range(wb.stages):
        assert wb.comm_volume(stage) == wp.comm_volume(stage)
        assert wb.comm_counts(stage) == wp.comm_counts(stage)
    for a, b in zip(wb.nodes, wp.nodes):
        assert a.comm == b.comm, a.name
