"""Batched-vs-compiled backend parity and batched-sweep semantics.

The batched backend (repro.core.batched) lowers each compiled structure
class into one jitted array kernel and replays whole batches of configs
at once.  It must reproduce the compiled backend — itself pinned exactly
against the sympy reference — within rel 1e-6 on every bundled
architecture in train and serve mode, which on CPU requires float64
(there is a regression test demonstrating float32 is NOT sufficient).

Tolerances: step/compute/comm/peak-memory components are compared at
rel 1e-6; exposed comm and bubble fraction are differences of
near-equal quantities (span - busy), so they are compared with an
absolute tolerance scaled by the step time instead of a relative one.
"""
import dataclasses

import pytest

from repro import Scenario, TPU_V5E
from repro.api import _batched_engines, _engines
from repro.configs import ARCHS, get
from repro.core.batched import REPLAYABLE_SCHEDULES, BatchedBackend
from repro.core.dse import evaluate_point_compiled

MODES = ("train", "serve")
REL = 1e-6

try:
    from benchmarks.paper_models import GPT3_5B
except ImportError:
    from repro.core import ModelSpec
    GPT3_5B = ModelSpec(name="gpt3-5b", n_layers=24, d_model=4096,
                        n_heads=32, n_kv_heads=32, d_ff=16384, vocab=51200,
                        gated_ffn=False)

GPT3_SMOKE = dataclasses.replace(GPT3_5B, name="gpt3-5b-smoke", n_layers=8,
                                 d_model=2048, n_heads=16, n_kv_heads=16,
                                 d_ff=8192, vocab=4096)


def _scenario(spec, mode):
    sc = Scenario(spec)
    if mode == "train":
        sc = sc.train(batch=8, seq=64)
    else:
        sc = sc.serve(batch=4, kv_len=128)
    return sc


def _cfgs(sc, spec):
    """One dense pp=1 config and one pipelined 1f1b config per case —
    two batch kernels, which keeps the jit-compile bill bounded while
    covering both scheduling paths of the batched evaluator."""
    ep = spec.moe is not None
    return [sc.parallel(dp=2, tp=2, sp=True, ep=ep).cfg,
            sc.parallel(dp=2, tp=2, sp=True, pp=2, microbatches=2,
                        ep=ep).cfg]


def _assert_sim_close(sim_b, sim_c, ctx):
    step = sim_c.step_time
    for attr in ("step_time", "compute_time", "comm_time"):
        a, b = getattr(sim_c, attr), getattr(sim_b, attr)
        assert abs(a - b) <= REL * max(abs(a), 1e-30), (ctx, attr, a, b)
    # span-minus-busy quantities: catastrophic cancellation makes a
    # relative bound meaningless, so bound the absolute error by step
    assert abs(sim_c.exposed_comm - sim_b.exposed_comm) <= REL * step, ctx
    assert abs(sim_c.bubble_fraction - sim_b.bubble_fraction) <= REL, ctx
    assert sim_b.schedule == sim_c.schedule, ctx


def _assert_mem_close(mem_b, mem_c, ctx):
    for f in ("weights", "grads", "opt_states", "master_params",
              "peak_activation", "recompute_extra", "peak_bytes"):
        a, b = getattr(mem_c, f), getattr(mem_b, f)
        assert abs(a - b) <= REL * max(abs(a), 1e-30), (ctx, f, a, b)
    assert mem_b.inflight_factor == mem_c.inflight_factor, ctx


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("name", ARCHS)
def test_batched_parity(name, mode):
    spec = get(name).smoke
    sc = _scenario(spec, mode)
    env = sc.env()
    engine = _engines.engine(sc.spec, sc.mode, env)
    bengine = _batched_engines.engine(sc.spec, sc.mode, env)
    cfgs = _cfgs(sc, spec)
    for recompute in ((False, True) if mode == "train" else (False,)):
        got = bengine.evaluate_many(cfgs, TPU_V5E, recompute=recompute)
        assert all(r is not None for r in got)
        for cfg, (sim_b, mem_b) in zip(cfgs, got):
            ref = evaluate_point_compiled(engine, cfg, TPU_V5E,
                                          recompute=recompute, reuse=True)
            ctx = (name, mode, cfg.describe(), recompute)
            _assert_sim_close(sim_b, ref.sim, ctx)
            _assert_mem_close(mem_b, ref.mem, ctx)


@pytest.mark.parametrize("sched", REPLAYABLE_SCHEDULES)
def test_batched_parity_schedules(sched):
    """Replayable pipeline schedules at pp=4: the planned-event replay
    scan must match the reference replay exactly (to float64)."""
    vs = 2 if sched == "interleaved" else 1
    sc = (Scenario(GPT3_SMOKE).train(batch=8, seq=128)
          .parallel(dp=2, pp=4, microbatches=8)
          .schedule(sched, vstages=vs))
    env = sc.env()
    engine = _engines.engine(sc.spec, sc.mode, env)
    bengine = _batched_engines.engine(sc.spec, sc.mode, env)
    got = bengine.evaluate_many([sc.cfg], TPU_V5E)
    assert got[0] is not None
    ref = evaluate_point_compiled(engine, sc.cfg, TPU_V5E, reuse=True)
    _assert_sim_close(got[0][0], ref.sim, sched)
    _assert_mem_close(got[0][1], ref.mem, sched)


def test_zb_h1_falls_back():
    """zb-h1 backfills weight-grad slots duration-dependently — not
    batch-replayable, so evaluate_many must decline (None) and the
    sweep must transparently take the per-config path instead."""
    sc = (Scenario(GPT3_SMOKE).train(batch=8, seq=128)
          .parallel(dp=2, pp=4, microbatches=8).schedule("zb-h1"))
    env = sc.env()
    bengine = _batched_engines.engine(sc.spec, sc.mode, env)
    assert bengine.evaluate_many([sc.cfg], TPU_V5E) == [None]
    assert not bengine.supports(sc.cfg, TPU_V5E)


def test_batched_sweep_matches_compiled():
    """Whole-sweep equivalence through the public API: same configs,
    same skip list, per-config results within the parity budget."""
    spec = get("qwen3-14b").smoke
    sc = Scenario(spec).train(batch=8, seq=64)
    kw = dict(microbatches=(1, 2), schedule=("1f1b", "gpipe"))
    ref = sc.sweep(8, **kw)
    got = sc.with_backend("batched").sweep(8, **kw)
    assert len(ref) == len(got) > 0
    assert len(ref.skipped) == len(got.skipped)
    by_label = {p.label: p for p in got}
    assert set(by_label) == {p.label for p in ref}
    for p in ref:
        q = by_label[p.label]
        _assert_sim_close(q.sim, p.sim, p.label)
        _assert_mem_close(q.mem, p.mem, p.label)
    bs = got.batch_stats
    assert bs is not None and bs["points"] >= len(got)
    assert "batched:" in got.summary()


def test_batched_backend_requires_x64():
    """Constructing the backend flips the x64 switch (guarded)."""
    import jax
    _scenario(get("qwen3-14b").smoke, "train")  # ensure jax imported
    assert jax.config.jax_enable_x64


def _sim_rel_err(backend, sc):
    sim_b, _ = backend.evaluate_many([sc.cfg], TPU_V5E, recompute=True)[0]
    ref = evaluate_point_compiled(_engines.engine(sc.spec, sc.mode, sc.env()),
                                  sc.cfg, TPU_V5E, recompute=True, reuse=True)
    return max(abs(getattr(ref.sim, a) - getattr(sim_b, a))
               / abs(getattr(ref.sim, a))
               for a in ("step_time", "compute_time", "comm_time"))


def test_float32_breaks_parity():
    """The 1e-6 budget genuinely needs float64: on a deep-pipeline
    32-layer config the float32-forced batched backend accumulates past
    the budget while the float64 default stays well inside it
    (regression guard for the x64 guard above)."""
    spec = dataclasses.replace(GPT3_SMOKE, name="gpt3-l32", n_layers=32)
    sc = Scenario(spec).train(batch=32, seq=512).parallel(
        dp=2, tp=2, sp=True, pp=4, microbatches=16)
    engine = _engines.engine(sc.spec, sc.mode, sc.env())
    assert _sim_rel_err(BatchedBackend(engine, dtype="float32"), sc) > REL
    assert _sim_rel_err(BatchedBackend(engine), sc) < REL / 100


def test_batch_bind_matches_local():
    """CostProgram.batch_bind is the vectorized _local: exact equality
    on every structure class of a small sweep."""
    import numpy as np
    from repro.core.dse import enumerate_configs
    spec = get("qwen3-14b").smoke
    sc = Scenario(spec).train(batch=8, seq=64)
    engine = _engines.engine(sc.spec, sc.mode, sc.env())
    cfgs = [c for c in enumerate_configs(8) if max(1, c.pp) == 1]
    progs = {}
    for cfg in cfgs:
        progs.setdefault(id(engine.program(cfg)), []).append(cfg)
    assert progs
    for group in progs.values():
        prog = engine.program(group[0])
        axes = tuple(sorted({a for c in group for a in c.axes}))
        ln, lb = prog.batch_bind([{a: c.axes.get(a, 1) for a in axes}
                                  for c in group], axes=axes)
        for j, cfg in enumerate(group):
            rn, rb = prog._local(cfg)
            assert np.array_equal(ln[j], rn), cfg.describe()
            assert np.array_equal(lb[j], rb), cfg.describe()


def test_batched_single_point_api():
    """A batched-backend Scenario still traces/simulates per point via
    the shared compiled engine (batched only changes sweep)."""
    sc = _scenario(get("qwen3-14b").smoke, "train") \
        .parallel(dp=2, tp=2, sp=True).with_backend("batched")
    ref = sc.with_backend("compiled").trace().simulate(TPU_V5E)
    got = sc.trace().simulate(TPU_V5E)
    assert got.step_time == ref.step_time
