"""Symbolic invariant prover (``STG6xx``) — whole-space certification.

Three guarantees under test:

* every bundled arch × train/serve certifies with ZERO diagnostics —
  the paper-level invariants (FLOP/comm conservation, guard partition,
  bound soundness, memory monotonicity) hold symbolically for the whole
  design space;
* every *seeded* violation — deleted/duplicated/flipped guards,
  corrupted shard exponents, broken wire formulas, an unsound floor —
  yields exactly its expected STG6xx code;
* certificate-driven pruning in ``search="bnb"`` returns a front
  identical to the uncertified search on the pinned 340-config space
  while visiting no more points (the certificate only replaces exact
  memory values with sound lower bounds).
"""
import pytest

from repro import Scenario
from repro.analysis import prove_space
from repro.analysis.diagnostics import Report
from repro.analysis.sarif import to_sarif
from repro.configs import ARCHS, get
from repro.core import compiled as compiled_mod
from repro.core import dse as dse_mod
from repro.core.assemble import total_layers
from repro.core.compiled import CompiledBackend
from repro.core.dse import SweepResult, enumerate_configs

WORLD = 8
SPACE = dict(microbatches=(1, 2, 4, 8), schedule=("1f1b", "gpipe"))


def _scenario(arch="qwen3-14b", mode="train"):
    spec = get(arch).smoke
    if mode == "train":
        return Scenario(spec).train(batch=32, seq=64)
    return Scenario(spec).decode(batch=4, kv_len=64)


def _fresh_engine(sc):
    """A private engine (NOT the process-wide cache) that corruption
    tests may mutate freely."""
    src = sc.builder()
    return CompiledBackend(lambda: src.clone().graph, sc.env(),
                           n_layers=total_layers(sc.spec))


# ---- clean spaces certify ---------------------------------------------------


@pytest.mark.parametrize("mode", ["train", "serve"])
@pytest.mark.parametrize("arch", ARCHS)
def test_all_archs_certify_clean(arch, mode):
    sc = _scenario(arch, mode)
    cert = sc.prove(WORLD)
    assert cert.ok, cert.report.render()
    assert not cert.report.diagnostics
    assert cert.partition_ok and cert.inflight_monotone
    assert cert.classes and all(c.ok for c in cert.classes)
    assert cert.lattice_points > 0
    assert "all invariants certified" in cert.summary()


def test_certificate_covers_every_config_of_the_space():
    """The lattice collapses mb/schedule dimensions: a 340-config space
    certifies off tens of lattice points."""
    sc = _scenario()
    cfgs = list(enumerate_configs(16, **SPACE))
    engine = _fresh_engine(sc)
    cert = prove_space(engine, cfgs=cfgs)
    assert cert.ok
    assert cert.configs == len(cfgs) == 340
    assert cert.lattice_points < len(cfgs) / 4
    assert cert.memory_monotone_programs()


# ---- seeded violations ------------------------------------------------------


def _prove_corrupted(corrupt):
    """Certify clean, apply ``corrupt(engine)``, re-prove; returns the
    second certificate."""
    sc = _scenario()
    engine = _fresh_engine(sc)
    cfgs = list(enumerate_configs(WORLD))
    clean = prove_space(engine, cfgs=cfgs)
    assert clean.ok, clean.report.render()
    corrupt(engine)
    return prove_space(engine, cfgs=cfgs)


def _guarded_prog(engine):
    for progs in engine.classes().values():
        for prog in progs:
            if prog.guards:
                return prog
    raise AssertionError("no guarded structure class compiled")


def test_seeded_guard_deletion():
    def corrupt(engine):
        prog = _guarded_prog(engine)
        prog.guards.pop(next(iter(prog.guards)))
    cert = _prove_corrupted(corrupt)
    assert not cert.ok
    assert "STG604" in cert.report.codes()


def test_seeded_guard_duplication():
    """A spurious extra predicate (the 'duplicated guard' seed) — vacuously
    true, so the class still matches its region — disagrees with the
    fresh distribution trace."""
    def corrupt(engine):
        prog = _guarded_prog(engine)
        (_val, axes), _ok = next(iter(prog.guards.items()))
        prog.guards[(0, axes)] = True       # 0 % deg == 0 for every deg
    cert = _prove_corrupted(corrupt)
    assert not cert.ok
    assert "STG604" in cert.report.codes()


def test_seeded_class_duplication():
    """Two structure classes claiming the same degrees break the
    partition: some config would match twice."""
    def corrupt(engine):
        for key, progs in engine._classes.items():
            for prog in progs:
                if prog.guards:
                    engine._classes[key].append(prog)
                    return
        raise AssertionError("no guarded structure class compiled")
    cert = _prove_corrupted(corrupt)
    assert not cert.ok
    assert "STG603" in cert.report.codes()
    assert not cert.partition_ok


def test_seeded_guard_flip():
    """Flipping a recorded predicate so the class widens into a point
    another class owns breaks disjointness (STG603).  (A flip that only
    *narrows* a class is self-healing — dispatch recompiles an honest
    twin for the abandoned region — so the seed picks a widening flip.)"""
    sc = _scenario()
    engine = _fresh_engine(sc)
    cfgs = list(enumerate_configs(WORLD))
    clean = prove_space(engine, cfgs=cfgs)
    assert clean.ok, clean.report.render()

    from repro.core.distribute import guards_match_degrees
    lattice: dict = {}
    for cfg in cfgs:
        key = CompiledBackend._structure_key(cfg)
        lattice.setdefault(key, set()).add(
            tuple(cfg.axes.get(a, 1) for a in key[0]))
    for key, progs in engine.classes().items():
        pts = [dict(zip(key[0], d)) for d in lattice.get(key, ())]
        for prog in progs:
            for gk, ok in prog.guards.items():
                trial = dict(prog.guards)
                trial[gk] = not ok
                if any(guards_match_degrees(trial, p) for p in pts):
                    prog.guards[gk] = not ok      # widen onto an owned point
                    cert = prove_space(engine, cfgs=cfgs)
                    assert not cert.ok
                    assert "STG603" in cert.report.codes()
                    assert not cert.partition_ok
                    return
    raise AssertionError("no widening guard flip available in this space")


def test_seeded_flop_corruption():
    """Doubling a shard exponent leaves a negative replication exponent
    — world-summed FLOPs no longer equal single-device times a {0,1}
    monomial."""
    def corrupt(engine):
        for progs in engine.classes().values():
            for prog in progs:
                for p in prog.nodes:
                    if p.flop and p.flop[0] == "scale":
                        t = p.flop[2]
                        if prog._t_part[t]:
                            a, _k = prog._t_part[t][0]
                            prog._t_part[t] = ((a, 2),)
                            return
        raise AssertionError("no sharded scale-flop tensor found")
    cert = _prove_corrupted(corrupt)
    assert not cert.ok
    assert "STG601" in cert.report.codes()


def test_seeded_comm_corruption(monkeypatch):
    """A wrong wire formula breaks the ring-term invariant against the
    independent comm_checks table."""
    def bad_wire(coll, size, n):
        return size * (n - 1) / n, n - 1          # AllReduce lost a phase
    sc = _scenario()
    engine = _fresh_engine(sc)
    cfgs = list(enumerate_configs(WORLD))
    prove_space(engine, cfgs=cfgs)                # compile clean classes
    monkeypatch.setattr(compiled_mod, "collective_wire", bad_wire)
    cert = prove_space(engine, cfgs=cfgs, retrace=False)
    assert not cert.ok
    assert "STG602" in cert.report.codes()


def test_seeded_unsound_floor(monkeypatch):
    """An inflated cell floor disagrees with the independent
    re-derivation at some lattice cell."""
    real = dse_mod._cell_floor

    def inflated(prog, cfg, hw, recompute, comm_ok):
        m, path, o = real(prog, cfg, hw, recompute, comm_ok)
        return m * 2 + 1e-6, path, o
    sc = _scenario()
    engine = _fresh_engine(sc)
    cfgs = list(enumerate_configs(WORLD))
    prove_space(engine, cfgs=cfgs)
    monkeypatch.setattr(dse_mod, "_cell_floor", inflated)
    cert = prove_space(engine, cfgs=cfgs, retrace=False)
    assert not cert.ok
    assert "STG605" in cert.report.codes()


def test_seeded_zbh1_bound_misuse(monkeypatch):
    """step_lower_bound applying the path term to pipelined zb-h1 would
    over-bound (zb-h1 splits weight-grads off the chunk chain) — caught
    behaviorally."""
    def unsound(cfg, floor):
        m, path, o = floor
        return max(cfg.microbatches * m, path) + o
    sc = _scenario()
    engine = _fresh_engine(sc)
    cfgs = list(enumerate_configs(WORLD))
    prove_space(engine, cfgs=cfgs)
    monkeypatch.setattr(dse_mod, "step_lower_bound", unsound)
    cert = prove_space(engine, cfgs=cfgs, retrace=False)
    assert not cert.ok
    assert "STG605" in cert.report.codes()


def test_seeded_memory_corruption():
    """A negative partition exponent makes local bytes GROW with the
    degree — the monotonicity certificate must refuse."""
    def corrupt(engine):
        for progs in engine.classes().values():
            for prog in progs:
                for t, pat in enumerate(prog._t_part):
                    if pat:
                        a, _k = pat[0]
                        prog._t_part[t] = ((a, -1),)
                        return
        raise AssertionError("no partitioned tensor found")
    cert = _prove_corrupted(corrupt)
    assert not cert.ok
    assert "STG606" in cert.report.codes()
    assert not cert.memory_monotone_programs() or any(
        not c.mem_monotone for c in cert.classes)


# ---- certificate-driven pruning ---------------------------------------------


def test_bnb_prove_front_and_visited_identical():
    sc = _scenario()
    plain = sc.sweep(16, search="bnb", **SPACE)
    proved = sc.sweep(16, search="bnb", prove=True, **SPACE)
    assert proved.certificates is not None and proved.certificates.ok
    assert proved.visited == plain.visited
    assert proved.total == plain.total == 328
    assert ([p.cfg.describe() for p in plain]
            == [p.cfg.describe() for p in proved])
    assert [p.sim.step_time for p in plain] \
        == [p.sim.step_time for p in proved]
    assert "proved:" in proved.summary()


def test_bnb_certificate_skips_memory_evaluations():
    from repro.obs import metrics
    sc = _scenario()
    before = metrics.counter("dse.bnb_cert_pruned").value
    sc.sweep(16, search="bnb", prove=True, **SPACE)
    assert metrics.counter("dse.bnb_cert_pruned").value > before


def test_sweep_full_attaches_certificates():
    sc = _scenario()
    res = sc.sweep(WORLD, search="full", prove=True)
    assert res.certificates is not None
    assert res.certificates.ok
    assert "proved:" in res.summary()


# ---- SweepResult.summary() robustness (satellite) ---------------------------


def test_summary_no_division_by_zero_at_empty_total():
    res = SweepResult([], [], backend="compiled", search="bnb",
                      evaluated=0, visited=0, total=0)
    s = res.summary()
    assert "n/a" in s


def test_summary_engine_hit_ratio_na_when_no_lookups():
    res = SweepResult([], [], backend="compiled",
                      engine_stats={"classes": 0, "compiles": 0, "hits": 0})
    assert "n/a hit ratio" in res.summary()


# ---- SARIF export (satellite) -----------------------------------------------


def test_sarif_structure_and_rule_metadata():
    rep = Report(name="unit")
    rep.add("STG601", "flops differ", node="mlp_up")
    rep.add("STG007", "infeasible", phase="fwd")
    doc = to_sarif([rep])
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    rules = {r["id"]: r for r in run["tool"]["driver"]["rules"]}
    assert "STG601" in rules and "STG606" in rules
    assert rules["STG601"]["defaultConfiguration"]["level"] == "error"
    results = run["results"]
    assert len(results) == 2
    assert results[0]["ruleId"] == "STG601"
    assert results[0]["level"] == "error"
    assert results[1]["level"] == "note"
    loc = results[0]["locations"][0]["logicalLocations"][0]
    assert "mlp_up" in loc["fullyQualifiedName"]


def test_sarif_cli_writes_file(tmp_path):
    import json

    from repro.analysis.__main__ import main
    sc = _scenario()
    tl = tmp_path / "tl.json"
    sc.parallel(dp=2).trace().timeline(str(tl))
    out = tmp_path / "out.sarif"
    rc = main([str(tl), "--timeline", "--sarif", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["runs"][0]["tool"]["driver"]["rules"]
