"""End-to-end behaviour tests for the reproduced system (STAGE + runtime)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ModelSpec, ParallelCfg, TPU_V5E, generate,
                        peak_memory, simulate)
from repro.core.dse import enumerate_configs, sweep


TINY = ModelSpec(name="sys", n_layers=4, d_model=128, n_heads=4,
                 n_kv_heads=2, d_ff=256, vocab=1024)


def _build():
    from repro.core import build_graph
    return build_graph(TINY, mode="train").graph


def test_dse_sweep_finds_tradeoff():
    """Paper Fig 8: DSE points trade runtime against memory."""
    from repro.core import bind_env
    env = bind_env(TINY, batch=16, seq=64)
    pts = sweep(_build, env, world=8, n_layers=TINY.n_layers, max_tp=4,
                microbatches=2)
    assert len(pts) >= 6
    best_time = pts[0]
    best_mem = min(pts, key=lambda p: p.peak_gb)
    assert best_time.step_ms <= best_mem.step_ms + 1e-9
    # FSDP variant of the same (dp,tp) uses less memory than plain DP
    by_label = {p.label: p for p in pts}
    for lbl, p in by_label.items():
        if "FSDP" in lbl:
            plain = by_label.get(lbl.replace(",FSDP", ""))
            if plain:
                assert p.peak_gb <= plain.peak_gb + 1e-6
                break


def test_generation_scales_subquadratically():
    """Paper Fig 13: generation cost grows mildly with system size."""
    import time
    times = {}
    for dp in (4, 64):
        cfg = ParallelCfg(axes={"dp": dp, "tp": 4}, dp_axis="dp",
                          tp_axis="tp", sp=True)
        t0 = time.time()
        generate(TINY, cfg, batch=dp * 4, seq=64)
        times[dp] = time.time() - t0
    # 16x more devices must cost < 4x generation time (symbolic reuse)
    assert times[64] < 4 * times[4] + 0.5


def test_end_to_end_counts_consistent():
    cfg = ParallelCfg(axes={"dp": 2, "tp": 2}, dp_axis="dp", tp_axis="tp",
                      sp=True)
    w, g, plan, env = generate(TINY, cfg, batch=8, seq=64)
    sim = simulate(w, TPU_V5E)
    mem = peak_memory(g, cfg, env, plan)
    assert sim.step_time > 0 and mem.peak_gb > 0
    assert w.total_flops() > 0
