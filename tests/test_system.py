"""End-to-end behaviour tests for the reproduced system (STAGE + runtime)."""
import pytest

from repro import Scenario, TPU_V5E, ModelSpec

TINY = ModelSpec(name="sys", n_layers=4, d_model=128, n_heads=4,
                 n_kv_heads=2, d_ff=256, vocab=1024)


def test_dse_sweep_finds_tradeoff():
    """Paper Fig 8: DSE points trade runtime against memory."""
    pts = Scenario(TINY).train(batch=16, seq=64).sweep(
        world=8, max_tp=4, microbatches=2)
    assert len(pts) >= 6
    best_time = pts[0]
    best_mem = min(pts, key=lambda p: p.peak_gb)
    assert best_time.step_ms <= best_mem.step_ms + 1e-9
    # FSDP variant of the same (dp,tp) uses less memory than plain DP
    by_label = {p.label: p for p in pts}
    for lbl, p in by_label.items():
        if "FSDP" in lbl:
            plain = by_label.get(lbl.replace(",FSDP", ""))
            if plain:
                assert p.peak_gb <= plain.peak_gb + 1e-6
                break


def test_generation_scales_subquadratically():
    """Paper Fig 13: generation cost grows mildly with system size."""
    import time
    # warm the graph cache so both timings measure the same warm path
    # (clone + distribute + instantiate), not cold-assembly vs cache-hit
    Scenario(TINY).builder()
    times = {}
    for dp in (4, 64):
        sc = (Scenario(TINY).train(batch=dp * 4, seq=64)
              .parallel(dp=dp, tp=4, sp=True))
        t0 = time.time()
        _ = sc.trace().workload
        times[dp] = time.time() - t0
    # 16x more devices must cost < 4x generation time (symbolic reuse)
    assert times[64] < 4 * times[4] + 0.5


def test_end_to_end_counts_consistent():
    tr = (Scenario(TINY).train(batch=8, seq=64)
          .parallel(dp=2, tp=2, sp=True).trace())
    sim = tr.simulate(TPU_V5E)
    mem = tr.memory()
    assert sim.step_time > 0 and mem.peak_gb > 0
    assert tr.total_flops() > 0
