"""Pipeline-schedule subsystem: IR generators, timing replay, in-flight
memory factors, microbatch feasibility validation, pinned compute/comm
time-accounting semantics, and the schedule-aware Chakra export."""
import json

import pytest

from repro import InfeasibleConfigError, ParallelCfg, Scenario, TPU_V5E
from repro.core import ModelSpec
from repro.core.schedules import (SCHEDULES, build_schedule, inflight_factor,
                                  replay)

TINY = ModelSpec(name="tiny", n_layers=4, d_model=256, n_heads=8,
                 n_kv_heads=4, d_ff=512, vocab=4096)


def _uniform_dur(tf=1.0, tb=2.0, v=1):
    """Per-slot durations for a uniform pipeline (chunks carry 1/v of a
    stage; zb splits backward evenly)."""
    def dur(slot):
        if slot.kind == "fwd":
            return tf / v
        if slot.kind == "bwd":
            return tb / v
        return tb / (2 * v)          # bwd_in / bwd_w
    return dur


# ---- IR generators ---------------------------------------------------------

@pytest.mark.parametrize("pp,mb", [(2, 4), (4, 8), (8, 16), (4, 2)])
def test_1f1b_inflight_matches_closed_form(pp, mb):
    for s in range(pp):
        assert inflight_factor("1f1b", pp, mb, 1, s) == min(mb, pp - s)


def test_gpipe_holds_all_microbatches():
    for s in range(4):
        assert inflight_factor("gpipe", 4, 8, 1, s) == 8


def test_zb_h1_matches_1f1b_memory():
    # zero-bubble H1's selling point: activations die at bwd_in, so the
    # in-flight bound equals 1F1B's
    for s in range(4):
        assert inflight_factor("zb-h1", 4, 8, 1, s) == \
               inflight_factor("1f1b", 4, 8, 1, s)


def test_interleaved_inflight_exceeds_1f1b():
    # Megatron's documented memory penalty for virtual stages
    for s in range(4):
        assert inflight_factor("interleaved", 4, 8, 2, s) > \
               inflight_factor("1f1b", 4, 8, 1, s)


def test_every_slot_appears_exactly_once():
    for name in SCHEDULES:
        sched = build_schedule(name, 4, 8, 2 if name == "interleaved" else 1)
        for s, tl in enumerate(sched.timelines):
            fwd = [(x.mb, x.vstage) for x in tl if x.kind == "fwd"]
            rel = [(x.mb, x.vstage) for x in tl if x.kind in ("bwd", "bwd_in")]
            assert len(fwd) == len(set(fwd)) == 8 * sched.vstages, (name, s)
            assert sorted(fwd) == sorted(rel), (name, s)
            for x in tl:
                assert x.vstage % sched.pp == s, (name, x)


# ---- timing replay ---------------------------------------------------------

def test_1f1b_replay_equals_closed_form_uniform():
    for pp, mb in ((2, 4), (4, 8), (8, 16)):
        rep = replay(build_schedule("1f1b", pp, mb), _uniform_dur())
        assert rep.makespan == pytest.approx((mb + pp - 1) * 3.0)


def test_bubble_ordering_uniform():
    pp, mb = 4, 8
    bubbles = {}
    for name in SCHEDULES:
        v = 2 if name == "interleaved" else 1
        rep = replay(build_schedule(name, pp, mb, v), _uniform_dur(v=v))
        bubbles[name] = rep.bubble_fraction
    assert bubbles["gpipe"] >= bubbles["1f1b"] - 1e-12
    assert bubbles["1f1b"] > bubbles["interleaved"]
    assert bubbles["interleaved"] > bubbles["zb-h1"]


def test_zb_h1_replay_hits_theoretical_bubble():
    # ZB-H1 bubble = (pp-1) * (tf + tb_in - tb_w)   [Qi et al.]
    pp, mb, tf, tb = 4, 8, 1.0, 2.0
    rep = replay(build_schedule("zb-h1", pp, mb), _uniform_dur(tf, tb))
    assert rep.makespan == pytest.approx(mb * (tf + tb) + (pp - 1) * tf)


def test_interleaved_requires_divisible_microbatches():
    with pytest.raises(InfeasibleConfigError, match="divisible"):
        build_schedule("interleaved", 4, 6, 2)


# ---- ParallelCfg validation ------------------------------------------------

def test_cfg_rejects_bad_schedule_fields():
    with pytest.raises(ValueError, match="schedule"):
        ParallelCfg(schedule="pipedream")
    with pytest.raises(ValueError, match="microbatches"):
        ParallelCfg(microbatches=0)
    with pytest.raises(ValueError, match="interleaved"):
        ParallelCfg(schedule="1f1b", vstages=2)


def test_cfg_describe_includes_microbatches_and_schedule():
    cfg = ParallelCfg(axes={"dp": 2}, dp_axis="dp", pp=4, microbatches=8,
                      schedule="interleaved", vstages=2)
    d = cfg.describe()
    assert "mb=8" in d and "interleaved" in d and "v2" in d
    # default schedule stays out of the label (backward compatible)
    assert "1f1b" not in ParallelCfg(pp=2, microbatches=4).describe()


def test_validate_workload_microbatch_divisibility():
    cfg = ParallelCfg(axes={"dp": 4}, dp_axis="dp", microbatches=3)
    with pytest.raises(InfeasibleConfigError, match="per-dp-rank"):
        cfg.validate_workload(batch=16)          # 16/4 = 4, 4 % 3 != 0
    cfg.validate_workload(batch=24)              # 24/4 = 6 — fine


def test_sweep_skips_indivisible_microbatching():
    res = Scenario(TINY).train(batch=16, seq=64).sweep(16, microbatches=4)
    assert any("per-dp-rank" in s.reason for s in res.skipped)
    assert all("mb=4" in p.label for p in res)


def test_sweep_over_schedules_dedupes_pp1():
    sc = Scenario(TINY).train(batch=16, seq=64)
    base = sc.sweep(8, microbatches=4, schedule="1f1b")
    multi = sc.sweep(8, microbatches=4,
                     schedule=("1f1b", "gpipe", "zb-h1"), vstages=1)
    n_pp1 = sum(1 for p in base if p.cfg.pp == 1)
    n_pp = len(base) - n_pp1
    # pp=1 points are schedule-independent and enumerated once
    assert len(multi) == n_pp1 + 3 * n_pp
    scheds = {p.cfg.schedule for p in multi if p.cfg.pp > 1}
    assert scheds == {"1f1b", "gpipe", "zb-h1"}


# ---- simulator semantics (pinned) ------------------------------------------

def test_compute_comm_time_semantics():
    """The optimizer runs once per step: per-step stream busy time is
    microbatch busy x M + optimizer busy, NOT (microbatch + opt) x M —
    the regression behind the old dead conditional
    ``compute_busy * (mb if pp == 1 else mb)``."""
    tr = Scenario(TINY).train(batch=8, seq=64).parallel(
        dp=2, pp=2, microbatches=4).trace()
    sim = tr.simulate(TPU_V5E)
    mb = 4
    assert sim.compute_time == max(
        st.compute_busy * mb + st.opt_compute for st in sim.stages)
    assert sim.comm_time == max(
        st.comm_busy * mb + st.opt_comm for st in sim.stages)
    assert sim.exposed_comm == max(
        st.exposed_comm * mb + st.opt_exposed for st in sim.stages)
    assert 0.0 <= sim.overlap_ratio <= 1.0
    # opt busy must not scale with microbatch count
    s1 = tr.simulate(TPU_V5E, microbatches=1)
    s8 = tr.simulate(TPU_V5E, microbatches=8)
    opt = max(st.opt_compute for st in s1.stages)
    per_mb = max(st.compute_busy for st in s1.stages)
    assert s8.compute_time == pytest.approx(per_mb * 8 + opt)


def test_simulate_schedule_override():
    tr = Scenario(TINY).train(batch=8, seq=64).parallel(
        dp=2, pp=2, microbatches=4).trace()
    default = tr.simulate(TPU_V5E)
    assert default.schedule == "1f1b"
    zb = tr.simulate(TPU_V5E, schedule="zb-h1")
    assert zb.schedule == "zb-h1"
    assert zb.bubble_fraction < default.bubble_fraction
    assert zb.step_time < default.step_time


def test_schedule_parallel_compose_in_either_order():
    """.schedule() before .parallel() must survive the cfg rebuild."""
    a = (Scenario(TINY).train(batch=8, seq=64)
         .schedule("zb-h1").parallel(dp=2, pp=4, microbatches=8))
    b = (Scenario(TINY).train(batch=8, seq=64)
         .parallel(dp=2, pp=4, microbatches=8).schedule("zb-h1"))
    assert a.cfg == b.cfg and a.cfg.schedule == "zb-h1"
    c = (Scenario(TINY).train(batch=8, seq=64)
         .schedule("interleaved", vstages=2)
         .parallel(dp=2, pp=4, microbatches=8))
    assert c.cfg.schedule == "interleaved" and c.cfg.vstages == 2
    # an explicit parallel(schedule=...) still wins; the inherited
    # chunking quietly resets for a schedule that cannot use it
    d = c.parallel(dp=2, pp=4, microbatches=8, schedule="gpipe")
    assert d.cfg.schedule == "gpipe" and d.cfg.vstages == 1
    # ...but an EXPLICIT contradictory vstages surfaces the validation
    with pytest.raises(ValueError, match="interleaved"):
        Scenario(TINY).train(batch=8, seq=64).parallel(
            pp=4, microbatches=8, vstages=2)        # forgot schedule=
    with pytest.raises(ValueError, match="interleaved"):
        Scenario(TINY).train(batch=8, seq=64).schedule("zb-h1", vstages=2)


def test_simulate_override_must_match_pipeline_cut():
    """An interleaved-cut workload bakes chunk assignment into its nodes;
    replaying a different granularity over it would silently drop chunk
    durations, so it raises instead."""
    tr = (Scenario(TINY).train(batch=8, seq=64)
          .parallel(dp=2, pp=2, microbatches=4)
          .schedule("interleaved", vstages=2).trace())
    with pytest.raises(ValueError, match="pipeline cut"):
        tr.simulate(TPU_V5E, schedule="1f1b")
    with pytest.raises(ValueError, match="pipeline cut"):
        tr.simulate(TPU_V5E, vstages=1)
    assert tr.simulate(TPU_V5E).step_time > 0      # matching replay fine


def test_interleaved_plan_assigns_chunks():
    tr = (Scenario(TINY).train(batch=8, seq=64)
          .parallel(dp=2, pp=2, microbatches=4)
          .schedule("interleaved", vstages=2)
          .with_backend("sympy").trace())
    plan = tr.plan
    assert plan.vstages == 2 and plan.chunks == 4
    chunks = set(plan.op_vstage.values())
    assert chunks == {0, 1, 2, 3}
    for uid, c in plan.op_vstage.items():
        assert plan.op_stage[uid] == c % 2
    # each physical stage hosts two non-adjacent chunks
    assert tr.workload.vstages_of(0) == [0, 2]
    assert tr.workload.vstages_of(1) == [1, 3]
    # more chunk boundaries -> more P2P than the plain 2-stage cut
    plain = (Scenario(TINY).train(batch=8, seq=64)
             .parallel(dp=2, pp=2, microbatches=4)
             .with_backend("sympy").trace())
    assert len(plan.sendrecvs) > len(plain.plan.sendrecvs)


def test_recompute_still_reduces_memory_and_slows_bwd():
    tr = Scenario(TINY).train(batch=8, seq=64).parallel(
        dp=2, pp=2, microbatches=4).trace()
    plain = tr.simulate(TPU_V5E)
    rec = tr.simulate(TPU_V5E, recompute=True)
    assert rec.step_time > plain.step_time
    assert all(r.t_bwd > p.t_bwd for r, p in zip(rec.stages, plain.stages))


# ---- Chakra export: SendRecv ids + schedule expansion ----------------------

def _trace(pp=2, mb=2, sched="1f1b", v=1):
    return (Scenario(TINY).train(batch=8, seq=64)
            .parallel(dp=2, pp=pp, microbatches=mb)
            .schedule(sched, vstages=v).trace())


def test_sendrecv_recv_ids_collision_free():
    """The recv node id scheme (``-n.uid``) must never collide with any
    other node id in the stage trace (op uids start at 1, so 0 is never
    ambiguous)."""
    tr = _trace()
    for stage in (0, 1):
        t = tr.chakra_stage(stage)
        ids = [nd["id"] for nd in t["nodes"]]
        assert len(ids) == len(set(ids))
        recvs = [nd for nd in t["nodes"] if nd["type"] == "COMM_RECV_NODE"]
        assert recvs, "pp=2 stage must receive cross-stage tensors"
        for nd in recvs:
            assert nd["id"] < 0 and -nd["id"] in set(ids)


def test_export_ranks_roundtrip_pp2_cross_stage_deps(tmp_path):
    tr = _trace()
    n = tr.export_chakra(str(tmp_path))
    assert n == tr.workload.cfg.world == 4
    for rank in range(4):
        got = json.load(open(tmp_path / f"rank{rank}.json"))
        ids = {nd["id"] for nd in got["nodes"]}
        for nd in got["nodes"]:
            for d in nd["data_deps"]:
                assert d in ids, (rank, nd["id"], d)
        # every send is consumed by its recv twin inside the same rank
        sends = {nd["id"] for nd in got["nodes"]
                 if nd["type"] == "COMM_SEND_NODE"}
        recv_deps = {d for nd in got["nodes"]
                     if nd["type"] == "COMM_RECV_NODE"
                     for d in nd["data_deps"]}
        assert sends == recv_deps


@pytest.mark.parametrize("sched", SCHEDULES)
def test_expanded_export_replays_schedule(sched, tmp_path):
    v = 2 if sched == "interleaved" else 1
    tr = _trace(pp=2, mb=4, sched=sched, v=v)
    for stage in (0, 1):
        t = tr.chakra_stage(stage, expand_microbatches=True)
        ids = [nd["id"] for nd in t["nodes"]]
        assert len(ids) == len(set(ids)), "instance ids collide"
        idset = set(ids)
        for nd in t["nodes"]:
            assert all(d in idset for d in nd["data_deps"])
            assert all(d in idset for d in nd["ctrl_deps"])
        # every microbatch instance present; opt stamped exactly once
        base = tr.chakra_stage(stage)
        n_mb = sum(1 for nd in base["nodes"] if nd["attrs"]["phase"] != "opt")
        n_opt = len(base["nodes"]) - n_mb
        assert len(t["nodes"]) == n_mb * 4 + n_opt
        # control chain follows slot order: fwd of mb 0 precedes bwd of mb 0
        first_of = {}
        for i, nd in enumerate(t["nodes"]):
            key = (nd["attrs"]["phase"], nd["attrs"].get("mb"))
            first_of.setdefault(key, i)
        assert first_of[("fwd", 0)] < first_of[("bwd", 0)]
    # optimizer nodes depend on every microbatch's grad instance
    t = tr.chakra_stage(1, expand_microbatches=True)
    opt = [nd for nd in t["nodes"] if nd["attrs"]["phase"] == "opt"
           and nd["data_deps"]]
    assert opt
    stride = max(abs(i) for i in (nd["id"] for nd in t["nodes"])) + 1
    mbs_per_opt = max(len(nd["data_deps"]) for nd in opt)
    assert mbs_per_opt >= 4
